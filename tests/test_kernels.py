"""Pallas kernels vs pure-jnp oracles: shape x dtype sweeps in interpret mode."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import decode_reference, mha_reference, rmsnorm_reference
from repro.kernels.rmsnorm import rmsnorm


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


FLASH_CASES = [
    # (B, Hq, Hkv, S, T, D, bq, bk)
    (1, 2, 2, 128, 128, 64, 64, 64),      # MHA
    (2, 4, 2, 256, 256, 64, 128, 64),     # GQA group=2
    (1, 8, 1, 128, 128, 128, 64, 128),    # MQA, MXU-aligned head dim
    (1, 4, 4, 512, 512, 32, 128, 128),    # long-ish seq
    (2, 2, 2, 64, 64, 8, 64, 64),         # tiny head dim
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(case, dtype, causal):
    B, Hq, Hkv, S, T, D, bq, bk = case
    rng = np.random.default_rng(hash((case, str(dtype), causal)) % 2 ** 31)
    q = _rand(rng, (B, Hq, S, D), dtype)
    k = _rand(rng, (B, Hkv, T, D), dtype)
    v = _rand(rng, (B, Hkv, T, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    want = mha_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


DECODE_CASES = [
    # (B, Hq, Hkv, T, D, bk)
    (1, 4, 4, 128, 64, 64),
    (2, 8, 2, 256, 64, 128),     # GQA group=4
    (3, 8, 1, 512, 128, 256),    # MQA
    (2, 4, 4, 64, 32, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    B, Hq, Hkv, T, D, bk = case
    rng = np.random.default_rng(hash((case, str(dtype))) % 2 ** 31)
    q = _rand(rng, (B, Hq, D), dtype)
    k = _rand(rng, (B, Hkv, T, D), dtype)
    v = _rand(rng, (B, Hkv, T, D), dtype)
    # partial fills, including boundary crossing a block edge
    kv_len = jnp.asarray(rng.integers(1, T + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, k, v, kv_len, block_k=bk, interpret=True)
    want = decode_reference(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_full_cache():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, T, D = 2, 4, 2, 256, 64
    q = _rand(rng, (B, Hq, D), jnp.float32)
    k = _rand(rng, (B, Hkv, T, D), jnp.float32)
    v = _rand(rng, (B, Hkv, T, D), jnp.float32)
    kv_len = jnp.full((B,), T, jnp.int32)
    out = decode_attention(q, k, v, kv_len, block_k=64, interpret=True)
    want = decode_reference(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (1, 256), (17, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(shape, dtype):
    rng = np.random.default_rng(hash((shape, str(dtype))) % 2 ** 31)
    x = _rand(rng, shape, dtype)
    scale = _rand(rng, (shape[-1],), dtype) + 1.0
    out = rmsnorm(x, scale, interpret=True, block_rows=8)
    want = rmsnorm_reference(x, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_vs_model_attention():
    """Kernel agrees with the model's chunked-jnp attention path."""
    from repro.configs import get_config
    from repro.models.layers import attention_core
    cfg = get_config("deepseek-7b").reduced().replace(attn_chunk=32)
    rng = np.random.default_rng(1)
    B, S, H, D = 2, 128, cfg.n_heads, cfg.head_dim
    q = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32)
    v = _rand(rng, (B, S, H, D), jnp.float32)
    want = attention_core(cfg, q, k, v, causal=True)
    got = flash_attention(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=True,
                          block_q=64, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(got, 1, 2)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)
