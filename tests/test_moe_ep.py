"""Experimental explicit-EP MoE (shard_map + psum combine): numerics and
gradients validated on a real small mesh. The 512-way production lowering
currently trips an XLA SPMD partitioner CHECK failure (partial-manual
shard_map nested in scan+remat) — documented in EXPERIMENTS.md §Perf HC2.6;
this test pins the correctness contract for when the compiler path opens up.
"""
import json
import subprocess
import sys
import textwrap

import pytest

pytestmark = [
    pytest.mark.slow,  # skipped by scripts/ci.sh --fast
    pytest.mark.skipif(
        __import__("repro.jax_compat", fromlist=["AxisType"]).AxisType is None,
        reason="partial-manual shard_map trips an XLA SPMD partitioner CHECK "
               "on jax<0.5 (see EXPERIMENTS pin in the module docstring)"),
]

PROBE = textwrap.dedent("""
    import os, json, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh, set_mesh
    from repro.models.moe import apply_moe, init_moe
    from repro.models import partitioning as part

    mesh = make_mesh((2, 2), ("data", "model"))
    cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(
        n_experts=4, top_k=2, capacity_factor=4.0)
    p = init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    with part.activation_axes("data", "model"), set_mesh(mesh):
        oe, ae = jax.jit(lambda p, x: apply_moe(
            cfg.replace(moe_impl="ep"), p, x))(p, x)
        g = jax.jit(jax.grad(lambda p, x: apply_moe(
            cfg.replace(moe_impl="ep"), p, x)[0].sum()))(p, x)
    orr, ar = apply_moe(cfg.replace(moe_impl="ragged"), p, x)
    err = float(jnp.max(jnp.abs(oe - orr)))
    gfin = all(bool(jnp.isfinite(l.astype(jnp.float32)).all())
               for l in jax.tree.leaves(g))
    print(json.dumps({"err": err, "aux_match": abs(float(ae) - float(ar)) < 1e-3,
                      "grads_finite": gfin}))
""")


def test_ep_matches_ragged_on_mesh_with_grads():
    out = subprocess.run([sys.executable, "-c", PROBE], cwd="/root/repo",
                         capture_output=True, text=True, timeout=400)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 5e-2
    assert r["aux_match"]
    assert r["grads_finite"]
