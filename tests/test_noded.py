"""Node daemon: PeerStub parity, directory-over-RPC, multi-process
lifecycle (DESIGN.md §11)."""
from __future__ import annotations

import glob
import hashlib
import os
import signal
import time

import numpy as np
import pytest

from repro.core.cache import Tier
from repro.core.directory import make_directory
from repro.core.mrm import ModelKey
from repro.core.noded import (DirectoryClient, DirectoryService, NodeDaemon,
                              PeerStub, spawn_node, sync_directory)
from repro.core.objectstore import ObjectStore
from repro.core.store import DiskStore, write_model
from repro.core.transport import (LoopbackTransport, SocketTransport,
                                  TransportError)


def make_model(root: str, key: ModelKey, kib: int = 256,
               seed: int = 0) -> str:
    disk = DiskStore(root)
    rng = np.random.RandomState(seed)
    n = max(1, (kib << 10) // (4 * 256))
    tensors = {f"w{i}": rng.rand(n, 64).astype(np.float32)
               for i in range(4)}
    path = disk.path_for(key)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    write_model(path, tensors, {"framework": key[0], "name": key[1],
                                "version": key[2]})
    h = hashlib.sha256()
    h.update(open(path, "rb").read())
    return h.hexdigest()


@pytest.fixture
def two_daemons(tmp_path):
    """Daemon a (hosts the sharded directory, holds m0 on disk) and
    daemon b (cold), both in-process, linked by real unix sockets."""
    osroot = str(tmp_path / "objstore")
    os.makedirs(osroot)
    key = ModelKey("jax", "m0", "1")
    digest = make_model(str(tmp_path / "a"), key)
    ObjectStore(osroot).put_file(
        key, DiskStore(str(tmp_path / "a")).path_for(key))
    a = NodeDaemon({"name": "a", "disk_root": str(tmp_path / "a"),
                    "listen": f"unix:{tmp_path}/a.sock",
                    "objectstore": {"root": osroot},
                    "directory": {"serve": True, "policy": "sharded",
                                  "n_shards": 4}})
    os.makedirs(tmp_path / "b")
    b = NodeDaemon({"name": "b", "disk_root": str(tmp_path / "b"),
                    "listen": f"unix:{tmp_path}/b.sock",
                    "objectstore": {"root": osroot},
                    "directory": {"connect": a.address}})
    yield a, b, key, digest
    b.shutdown()
    a.shutdown()


class TestPeerStubParity:
    """PeerStub over LoopbackTransport(daemon.handle) answers exactly
    like the in-process ClusterNode surface it proxies."""

    def test_surface_matches_direct(self, tmp_path):
        key = ModelKey("jax", "m0", "1")
        make_model(str(tmp_path / "d"), key)
        d = NodeDaemon({"name": "d", "disk_root": str(tmp_path / "d"),
                        "listen": f"unix:{tmp_path}/d.sock"})
        try:
            stub = PeerStub(LoopbackTransport(d.handle), "d")
            node = d.node
            assert stub.has_model(key) == node.has_model(key) is True
            assert stub.model_nbytes(key) == node.model_nbytes(key)
            assert stub.has_model(ModelKey("jax", "nope", "1")) is False
            assert stub.model_nbytes(ModelKey("jax", "nope", "1")) is None
            # whole-file read: byte-identical to the disk copy
            got = []
            n = stub.read_model(key, got.append)
            raw = open(d.mrm.disk.path_for(key), "rb").read()
            assert b"".join(got) == raw and n == len(raw)
            # ranges slice out of the same file
            assert stub.read_model_ranges(key, [(0, 64), (100, 32)]) == \
                raw[:64] + raw[100:132]
            # remote stubs never expose a local path (raw wire only)
            assert stub.local_model_path(key) is None
            assert node.local_model_path(key) is not None
            assert stub.remote and not node.remote
        finally:
            d.shutdown()

    def test_dead_peer_probes_degrade_not_raise(self, tmp_path):
        stub = PeerStub(SocketTransport(f"unix:{tmp_path}/gone.sock",
                                        timeout_s=0.5), "ghost")
        key = ModelKey("jax", "m0", "1")
        assert stub.has_model(key) is False
        assert stub.model_nbytes(key) is None
        assert stub.has_shard(key, 0) is False
        with pytest.raises(OSError):
            stub.read_model(key, lambda b: None)

    def test_read_model_counts_sink_bytes_not_server_claim(self):
        """read_model must validate against the bytes the sink actually
        received — a server-reported nbytes would let a truncated or
        duplicated stream pass the caller's size check."""
        class _LyingTransport:
            address = "fake:"

            def __init__(self, payload, claim):
                self.payload, self.claim = payload, claim

            def call_stream(self, req, sink):
                sink(self.payload)
                return {"ok": True, "nbytes": self.claim}

        key = ModelKey("jax", "m0", "1")
        got = []
        n = PeerStub(_LyingTransport(b"abcd", 4), "ok").read_model(
            key, got.append)
        assert n == 4 and got == [b"abcd"]
        with pytest.raises(OSError, match="delivered 4 of 999"):
            PeerStub(_LyingTransport(b"abcd", 999), "liar").read_model(
                key, lambda b: None)


class TestDirectoryOverRPC:
    def test_client_roundtrip(self, two_daemons):
        a, b, key, _ = two_daemons
        # b registered over RPC at daemon-a's directory; both are listed
        d = a.dir_service.directory
        names = {n.name for n in d.nodes()}
        assert names == {"a", "b"}
        # a's disk copy was published through the service at init
        assert ("a", Tier.DISK) in d.holders(key)
        # b's client resolves a to a PeerStub at a's advertised address
        peer = b.directory.node("a")
        assert isinstance(peer, PeerStub) and peer.has_model(key)
        # publish/withdraw through the client round-trips
        k2 = ModelKey("jax", "ghost", "9")
        b.directory.publish("b", k2, Tier.HOST)
        assert b.directory.tier_on(k2, "b") == Tier.HOST
        b.directory.withdraw("b", k2, Tier.HOST)
        assert b.directory.tier_on(k2, "b") is None

    def test_cold_open_pulls_over_socket(self, two_daemons):
        a, b, key, digest = two_daemons
        t = SocketTransport(b.address)
        r = t.call({"op": "open", "key": list(key), "tier": "host",
                    "timeout": 60})
        assert r["timings"]["tier_hit"] == "peer"
        assert r["disk_digest"] == digest
        assert r["timings"]["wire_s"] > 0  # measured, not modeled
        # serve counted on a's side, fetch on b's
        assert a.node.metrics["peer_serves"] == 1
        assert b.node.metrics["peer_fetches"] == 1
        t.close()

    def test_hung_peer_times_out_and_falls_back(self, tmp_path):
        """A peer that accepts but never answers must surface as a fetch
        error (cloud fallback), not a hang."""
        import socket as socketlib
        import threading
        osroot = str(tmp_path / "objstore")
        os.makedirs(osroot)
        key = ModelKey("jax", "m0", "1")
        seed_root = str(tmp_path / "seed")
        make_model(seed_root, key)
        ObjectStore(osroot).put_file(
            key, DiskStore(seed_root).path_for(key))

        hung_path = str(tmp_path / "hung.sock")
        hung = socketlib.socket(socketlib.AF_UNIX)
        hung.bind(hung_path)
        hung.listen(4)
        conns = []
        threading.Thread(
            target=lambda: [conns.append(hung.accept()) for _ in range(9)],
            daemon=True).start()

        os.makedirs(tmp_path / "c")
        c = NodeDaemon({"name": "c", "disk_root": str(tmp_path / "c"),
                        "listen": f"unix:{tmp_path}/c.sock",
                        "objectstore": {"root": osroot},
                        "call_timeout_s": 0.5,
                        "directory": {"serve": True}})
        try:
            # a fake warm holder whose data plane never answers
            stub = PeerStub(SocketTransport(f"unix:{hung_path}",
                                            timeout_s=0.5), "hung")
            c.directory.register(stub)
            c.directory.publish("hung", key, Tier.DISK)
            t0 = time.perf_counter()
            fut = c.mrm.open_async(key, tier="host")
            h = fut.result(timeout=30)
            took = time.perf_counter() - t0
            assert h.timings.tier_hit == "cloud"  # fell through, no hang
            assert took < 10, f"hung peer stalled the open {took:.1f}s"
            c.mrm.close(h)
        finally:
            c.shutdown()
            hung.close()

    def test_addressless_member_has_probe_surface(self, tmp_path):
        """A member registered without an advertised address must look
        like a stale hint to planners (every probe misses), not crash
        the open with an AttributeError."""
        a = NodeDaemon({"name": "a", "disk_root": str(tmp_path / "a"),
                        "listen": f"unix:{tmp_path}/a.sock",
                        "directory": {"serve": True}})
        try:
            a.dir_service.handle({"op": "dir.register", "name": "ghost"})
            client = DirectoryClient(LoopbackTransport(a.dir_service.handle))
            key = ModelKey("jax", "m0", "1")
            ghost = client.node("ghost")
            assert ghost is not None and ghost.name == "ghost"
            assert ghost.remote
            assert ghost.has_model(key) is False
            assert ghost.model_nbytes(key) is None
            assert ghost.has_shard(key, 0) is False
            assert ghost.local_model_path(key) is None
            # the directory host's own planner sees the same surface
            rec = a.dir_service.directory.node("ghost")
            assert rec.has_model(key) is False
        finally:
            a.shutdown()

    def test_remote_registration_resolves_to_stub_on_host(self, two_daemons):
        """The directory-HOSTING process must plan against remote members
        through a live PeerStub (b registered over RPC with an address),
        so a reverse fetch a<-b probes real state instead of crashing."""
        a, b, key, _ = two_daemons
        rec = a.dir_service.directory.node("b")
        assert isinstance(rec, PeerStub) and rec.address == b.address
        assert rec.has_model(key) is False  # b is cold: real probe, miss
        t = SocketTransport(b.address)
        t.call({"op": "open", "key": list(key), "tier": "host",
                "timeout": 60})
        t.close()
        assert rec.has_model(key) is True  # warm now: a can plan a<-b

    def test_anti_entropy_sync_converges(self, two_daemons):
        a, b, key, _ = two_daemons
        # a third replica, private, learns the fleet purely via dir.sync
        d3 = make_directory("sharded", n_shards=4)
        t = SocketTransport(a.address)
        merged = sync_directory(d3, t)
        assert merged > 0
        holders = dict(d3.holders(key))
        assert "a" in holders
        # and a dropped node never resurrects through an old snapshot
        snap_stale = d3.export_snapshot()
        a.dir_service.directory.drop_node("b")
        sync_directory(d3, t)  # d3 learns the drop
        assert "b" not in {n.name for n in d3.nodes()}
        # replaying the stale snapshot (still lists b) must not revive it
        d3.merge_snapshot(snap_stale)
        assert "b" not in {n.name for n in d3.nodes()}
        t.close()


class TestWireCalibration:
    def test_observe_wire_thread_safe(self):
        """Concurrent gather threads feed the calibration; no sample may
        be dropped to an interleaved EWMA read-modify-write."""
        import threading
        from repro.core.costmodel import (MIN_WIRE_SAMPLE_BYTES,
                                          HardwareModel)
        hw = HardwareModel()
        n_threads, per = 8, 200
        nb = MIN_WIRE_SAMPLE_BYTES

        def worker():
            for _ in range(per):
                hw.observe_wire("peer", nb, 1e-3)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        cal = hw.wire_calibration()["peer"]
        assert cal["samples"] == n_threads * per
        assert cal["bytes"] == n_threads * per * nb
        # identical samples: the EWMA must land exactly on the one rate
        assert hw.peer_bw == pytest.approx(nb / 1e-3)


def wait_for(pred, timeout=10.0, interval=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


@pytest.mark.proc
class TestDaemonLifecycle:
    """Real subprocess daemons: SIGTERM cleanliness, shm hygiene,
    crash-restart incarnations."""

    def _spawn(self, tmp_path, register_daemon, name, extra):
        root = tmp_path / name
        root.mkdir(exist_ok=True)
        err = open(tmp_path / f"{name}.err", "w")
        p, info = spawn_node({"name": name, "disk_root": str(root),
                              "listen": f"unix:{tmp_path}/{name}-dp.sock",
                              **extra}, stderr=err)
        register_daemon(p)
        return p, info

    def test_sigterm_clean_shutdown(self, tmp_path, register_daemon):
        key = ModelKey("jax", "m0", "1")
        make_model(str(tmp_path / "a"), key)
        shm_before = set(glob.glob("/dev/shm/trims_*"))
        pa, ia = self._spawn(tmp_path, register_daemon, "a",
                             {"use_shm": True,
                              "directory": {"serve": True,
                                            "policy": "sharded",
                                            "n_shards": 4}})
        pb, ib = self._spawn(tmp_path, register_daemon, "b",
                             {"use_shm": True,
                              "directory": {"connect": ia["address"]}})
        ta = SocketTransport(ia["address"])
        tb = SocketTransport(ib["address"])
        # b pulls the model into its host tier -> owns a shm segment
        r = tb.call({"op": "open", "key": list(key), "tier": "host",
                     "timeout": 60})
        assert r["timings"]["tier_hit"] == "peer"
        assert set(glob.glob("/dev/shm/trims_*")) - shm_before
        holders = ta.call({"op": "dir.holders", "key": list(key)})["holders"]
        assert any(n == "b" for n, _ in holders)

        pb.send_signal(signal.SIGTERM)
        assert pb.wait(timeout=15) == 0, "SIGTERM exit must be clean"
        # withdrawn from the directory...
        holders = ta.call({"op": "dir.holders", "key": list(key)})["holders"]
        assert not any(n == "b" for n, _ in holders), holders
        # ...and every shm segment b owned is unlinked
        pa.send_signal(signal.SIGTERM)
        assert pa.wait(timeout=15) == 0
        leaked = set(glob.glob("/dev/shm/trims_*")) - shm_before
        assert not leaked, f"daemons leaked shm: {leaked}"
        ta.close(); tb.close()

    def test_spawn_ready_timeout_enforced_while_blocked(self, tmp_path):
        """A child that stays alive but never prints READY (deadlocked
        during init — here: its directory RPC hangs on a socket that
        accepts and never answers) must fail at ``ready_timeout_s``, not
        block forever inside readline."""
        import socket as socketlib
        hung_path = str(tmp_path / "hungdir.sock")
        hung = socketlib.socket(socketlib.AF_UNIX)
        hung.bind(hung_path)
        hung.listen(4)
        (tmp_path / "z").mkdir(exist_ok=True)
        try:
            t0 = time.monotonic()
            with pytest.raises(TimeoutError, match="never became ready"):
                spawn_node({"name": "z", "disk_root": str(tmp_path / "z"),
                            "listen": f"unix:{tmp_path}/z-dp.sock",
                            "call_timeout_s": 120,
                            "directory": {"connect": f"unix:{hung_path}"}},
                           ready_timeout_s=2.0)
            assert time.monotonic() - t0 < 30
        finally:
            hung.close()

    def test_restart_gets_new_incarnation(self, tmp_path, register_daemon):
        key = ModelKey("jax", "m0", "1")
        pa, ia = self._spawn(tmp_path, register_daemon, "a",
                             {"directory": {"serve": True,
                                            "policy": "sharded",
                                            "n_shards": 4}})
        make_model(str(tmp_path / "b"), key, seed=1)
        pb1, ib1 = self._spawn(tmp_path, register_daemon, "b",
                               {"directory": {"connect": ia["address"]}})
        ta = SocketTransport(ia["address"])
        gen0 = ta.call({"op": "dir.generation"})["generation"]
        assert any(n == "b" for n, _ in ta.call(
            {"op": "dir.holders", "key": list(key)})["holders"])

        pb1.kill()  # crash: no withdraw, hints go stale
        pb1.wait(timeout=10)
        # restart with an EMPTY disk: re-register supersedes (new
        # incarnation), and the stale DISK hint must not survive under
        # the new incarnation
        empty = tmp_path / "b"
        for f in glob.glob(str(empty / "**" / "*.trims"), recursive=True):
            os.unlink(f)
        pb2, ib2 = self._spawn(tmp_path, register_daemon, "b",
                               {"directory": {"connect": ia["address"]}})
        gen1 = ta.call({"op": "dir.generation"})["generation"]
        assert gen1 > gen0, "restart must bump the membership generation"
        holders = ta.call({"op": "dir.holders", "key": list(key)})["holders"]
        assert not any(n == "b" for n, _ in holders), \
            f"stale hint resurrected across restart: {holders}"
        ta.close()
