"""Predictive fleet-wide placement (DESIGN.md §13) + the PR's
correctness regressions.

Covers the periodic/diurnal detector on seeded synthetic traces, the
planner's action generation (pre-position before a predicted burst,
burst dedupe, gather-driven replication, membership rebalance, silence
on uniform traffic), ``apply`` against a real mini-cluster with
batch-class admission, and the two regressions: ``Cluster.scatter``
validates node names up front / rolls back on mid-scatter failure, and
``NextUsePredictor`` cap-eviction prefers one-shot records over live
streams (``drop_model`` wires ``forget``).
"""
import time

import numpy as np
import pytest

from repro.core import (Cluster, DiskStore, HardwareModel, MRM, ModelKey,
                        NextUsePredictor, ObjectStore, PLANNER_TENANT,
                        PeriodicPattern, PlacementPlanner, PlannerConfig,
                        RequestContext, TenantRegistry, planner_ctx)
from repro.core.placement import PlacementAction

MB = 1 << 20
SHARD = 256 << 10


def _tensors(nbytes=2 * MB, n=8, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32)
            for i in range(n)}


def _mrm(disk, dev=64 * MB, host=256 * MB, **kw):
    return MRM(disk, device_capacity=dev, host_capacity=host,
               hw=kw.pop("hw", HardwareModel()), **kw)


@pytest.fixture
def objstore(tmp_path):
    return ObjectStore(str(tmp_path / "cloud"), shard_bytes=SHARD)


def _cluster(tmp_path, objstore, n=3, populate=(), **mrm_kw):
    for key, seed in populate:
        objstore.put(key, _tensors(seed=seed))
    cluster = Cluster(objectstore=objstore)
    for i in range(n):
        cluster.add_node(f"node{i}",
                         _mrm(DiskStore(str(tmp_path / f"disk{i}")), **mrm_kw))
    return cluster


CFG = PlannerConfig(bin_s=1.0, min_bursts=3, min_arrivals=4, lead_s=1.0)


def _feed_periodic(p, key, period=5.0, n=6, node="node0", t0=0.25):
    """n bursts of one arrival each, exactly ``period`` apart."""
    for i in range(n):
        p.observe(key, node=node, now=t0 + i * period)


# ---------------------------------------------------------------- detector
class TestDetector:
    def test_periodic_trace_detected(self):
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        _feed_periodic(p, key, period=5.0, n=6)
        pat = p.pattern(key)
        assert isinstance(pat, PeriodicPattern)
        assert pat.period_s == pytest.approx(5.0, abs=CFG.bin_s)
        assert pat.bursts == 6 and pat.cv <= CFG.max_period_cv
        # the next predicted start is one period after the last burst
        nxt = pat.next_start_s(now=25.5)
        assert nxt == pytest.approx(25.0 + pat.period_s, abs=CFG.bin_s)

    def test_sparse_duty_window_reads_as_one_burst(self):
        """Arrivals inside a duty window leave empty bins; merge_gap_bins
        welds them into one run instead of shattering the period."""
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        for i in range(4):  # window = bins [0,2] with bin 1 empty
            base = i * 10.0
            p.observe(key, now=base + 0.1)
            p.observe(key, now=base + 2.1)
        pat = p.pattern(key)
        assert pat is not None
        assert pat.period_s == pytest.approx(10.0, abs=CFG.bin_s)

    def test_background_traffic_does_not_weld_bursts(self):
        """A thin uniform background under a strong periodic spike must
        not merge everything into one run (active_frac threshold)."""
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        for i in range(5):  # spikes: 8 arrivals at t = i*6
            for _ in range(8):
                p.observe(key, now=i * 6.0 + 0.1)
        for t in range(30):  # background: 1 arrival every bin
            p.observe(key, now=t + 0.5)
        pat = p.pattern(key)
        assert pat is not None
        assert pat.period_s == pytest.approx(6.0, abs=CFG.bin_s)

    def test_uniform_and_thin_traces_yield_no_pattern(self):
        import random
        p = PlacementPlanner(cfg=CFG)
        uni, thin = ModelKey("jax", "u", "1"), ModelKey("jax", "t", "1")
        rng = random.Random(7)
        for _ in range(200):  # uniform: every bin active -> one giant run
            p.observe(uni, now=rng.uniform(0.0, 30.0))
        p.observe(thin, now=1.0)  # below min_arrivals
        p.observe(thin, now=6.0)
        assert p.pattern(uni) is None
        assert p.pattern(thin) is None

    def test_irregular_gaps_fail_cv_gate(self):
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        for t in (0.5, 4.5, 14.5, 17.5, 30.5):  # gaps 4, 10, 3, 13
            p.observe(key, now=t)
        assert p.pattern(key) is None


# ------------------------------------------------------------------- plan()
class TestPlan:
    def test_preposition_fires_inside_lead_window_once(self):
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        _feed_periodic(p, key, period=5.0, n=6, node="node1")  # last at 25.25
        assert p.plan(now=26.0) == []           # burst at ~30 is > lead away
        acts = p.plan(now=29.5)                  # inside the 1s lead window
        assert [a.kind for a in acts] == ["preposition"]
        assert acts[0].key == key and "node1" in acts[0].nodes
        assert 29.5 < acts[0].at_s <= 30.5
        assert p.plan(now=29.6) == []            # deduped: same burst
        assert p.metrics["prepositions"] == 1

    def test_next_cycle_reacts_again(self):
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        _feed_periodic(p, key, period=5.0, n=6)
        assert len(p.plan(now=29.5)) == 1
        assert len(p.plan(now=34.5)) == 1        # the following burst
        assert p.metrics["prepositions"] == 2

    def test_no_signal_no_action(self):
        import random
        p = PlacementPlanner(cfg=CFG)
        rng = random.Random(3)
        for _ in range(300):
            p.observe(ModelKey("jax", f"m{rng.randrange(8)}", "1"),
                      node=f"node{rng.randrange(4)}",
                      now=rng.uniform(0.0, 30.0))
        for t in (5.0, 15.0, 29.0):
            assert p.plan(now=t) == []
        assert p.metrics["prepositions"] == 0

    def test_gather_origins_drive_replication(self):
        p = PlacementPlanner(cfg=CFG)
        key = ModelKey("jax", "m", "1")
        _feed_periodic(p, key, period=5.0, n=6, node="node0")
        for _ in range(CFG.replicate_min_gathers):
            p.observe(key, node="node2", now=25.3, kind="gather")
        acts = p.plan(now=29.5)
        kinds = {a.kind: a for a in acts}
        assert set(kinds) == {"replicate", "preposition"}
        assert kinds["replicate"].nodes == ("node2",)
        # the replicated node's gathers become local: no whole-model copy
        assert "node2" not in kinds["preposition"].nodes

    def test_membership_change_triggers_rebalance(self, tmp_path, objstore):
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=3, populate=[(key, 0)])
        cluster.scatter(key)
        p = PlacementPlanner(directory=cluster.directory, cfg=CFG)
        assert p.plan(now=0.0) == []             # first plan: snapshot only
        cluster.directory.drop_node("node1")     # generation bump
        acts = [a for a in p.plan(now=1.0) if a.kind == "rebalance"]
        assert len(acts) == 1 and acts[0].key == key
        assert set(acts[0].nodes) == {"node0", "node2"}
        assert p.metrics["rebalances"] == 1
        assert p.plan(now=2.0) == []             # stable generation: quiet


# ------------------------------------------------------------------ apply()
class TestApply:
    def test_preposition_prefetches_host_tier(self, tmp_path, objstore):
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(key, 0)])
        p = PlacementPlanner(cfg=CFG)
        _feed_periodic(p, key, period=5.0, n=6, node="node1")
        applied = p.apply(cluster, now=29.5)
        assert [a.kind for a in applied] == ["preposition"]
        node = cluster.node("node1")
        deadline = time.time() + 30.0
        while not (node.mrm.host.peek(key) is not None) and time.time() < deadline:
            time.sleep(0.01)
        assert (node.mrm.host.peek(key) is not None)       # warm, no handle taken
        assert p.metrics["actions_applied"] == 1

    def test_apply_carries_batch_class_context(self):
        ctx = planner_ctx()
        assert ctx.tenant == PLANNER_TENANT and ctx.slo_class == "batch"

    def test_replicate_scatters_shards(self, tmp_path, objstore):
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=3, populate=[(key, 0)])
        p = PlacementPlanner(cfg=CFG)
        _feed_periodic(p, key, period=5.0, n=6, node="node0")
        for _ in range(CFG.replicate_min_gathers):
            p.observe(key, node="node2", now=25.3, kind="gather")
        acts = [a for a in p.plan(now=29.5) if a.kind == "replicate"]
        p.apply(cluster, actions=acts)
        held = cluster.node("node2").local_shards(key)
        assert held, "replicate must land shard copies on the gather origin"

    def test_failed_action_does_not_abort_the_rest(self, tmp_path, objstore):
        k_bad = ModelKey("jax", "missing", "1")  # not in the object store
        k_good = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(k_good, 0)])
        p = PlacementPlanner(cfg=CFG)
        acts = [PlacementAction("replicate", k, ("node0",), at_s=0.0)
                for k in (k_bad, k_good)]
        applied = p.apply(cluster, actions=acts)
        assert [a.key for a in applied] == [k_good]
        assert p.metrics["apply_errors"] == 1


# ----------------------------------------------- batch prefetch admission
class TestPlannerAdmission:
    def _pressured_mrm(self, tmp_path, n_fill=4):
        """Both shared tiers >= pressure_frac full of pinned-by-handle
        models: the §12 admission gate reads them as saturated."""
        disk = DiskStore(str(tmp_path / "disk"))
        per = _tensors(nbytes=1 * MB, seed=9)
        per_n = sum(a.nbytes for a in per.values())
        cap = int(n_fill * per_n * 1.01)
        mrm = _mrm(disk, dev=cap, host=cap)
        TenantRegistry().attach(mrm)
        handles = []
        for i in range(n_fill):
            k = ModelKey("jax", f"fill{i}", "1")
            disk.put(k, _tensors(nbytes=1 * MB, seed=i))
            handles.append(mrm.open(k))
        return mrm, disk, handles

    def test_batch_prefetch_suppressed_under_pressure(self, tmp_path):
        mrm, disk, handles = self._pressured_mrm(tmp_path)
        key = ModelKey("jax", "wanted", "1")
        disk.put(key, _tensors(nbytes=1 * MB, seed=99))
        fut = mrm.prefetch(key, tier="host", ctx=planner_ctx())
        fut.result()
        assert fut.suppressed
        assert mrm.metrics["prefetch_suppressed"] == 1
        assert mrm.host.peek(key) is None
        for h in handles:
            mrm.close(h)

    def test_critical_open_unaffected_by_pressure(self, tmp_path):
        mrm, disk, handles = self._pressured_mrm(tmp_path)
        key = ModelKey("jax", "wanted", "1")
        disk.put(key, _tensors(nbytes=1 * MB, seed=99))
        for h in handles:  # release so the critical open can evict
            mrm.close(h)
        ctx = RequestContext(tenant="svc", slo_class="critical")
        h = mrm.open(key, ctx=ctx)
        assert np.asarray(h.weights["w0"]).nbytes > 0
        assert mrm.metrics["prefetch_suppressed"] == 0
        mrm.close(h)

    def test_contextless_prefetch_untouched(self, tmp_path):
        mrm, disk, handles = self._pressured_mrm(tmp_path)
        key = ModelKey("jax", "wanted", "1")
        disk.put(key, _tensors(nbytes=1 * MB, seed=99))
        fut = mrm.prefetch(key, tier="host")  # legacy call: no ctx
        fut.result()
        assert not fut.suppressed
        assert mrm.metrics["prefetch_suppressed"] == 0
        for h in handles:
            mrm.close(h)


# ----------------------------------------------------- scatter regressions
class TestScatterRegressions:
    def test_unknown_node_rejected_up_front(self, tmp_path, objstore):
        """[bugfix] a bad name used to KeyError mid-loop, leaving the
        shards already placed published; now it rejects before placing."""
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(key, 0)])
        with pytest.raises(KeyError, match="unknown node"):
            cluster.scatter(key, node_names=["node0", "nope"])
        for name in ("node0", "node1"):
            assert cluster.node(name).local_shards(key) == []
            assert cluster.directory.shards_on(key, name) == []

    def test_midscatter_failure_rolls_back(self, tmp_path, objstore,
                                           monkeypatch):
        """[bugfix] a store_shard failure partway through withdraws the
        placements already published — no phantom holders."""
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(key, 0)])
        n_shards = len(objstore.shard_table(key))
        assert n_shards >= 3
        victim = cluster.node("node1")
        real = victim.store_shard
        calls = {"n": 0}

        def flaky(key, index, data):
            calls["n"] += 1
            if calls["n"] == 2:
                raise OSError("disk full")
            return real(key, index, data)

        monkeypatch.setattr(victim, "store_shard", flaky)
        with pytest.raises(OSError):
            cluster.scatter(key, node_names=["node1"])
        assert victim.local_shards(key) == []
        assert cluster.directory.shards_on(key, "node1") == []
        assert all(cluster.directory.shard_holders(key, i) == []
                   for i in range(n_shards))

    def test_successful_scatter_unchanged(self, tmp_path, objstore):
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(key, 0)])
        out = cluster.scatter(key)
        n_shards = len(objstore.shard_table(key))
        assert sum(len(v) for v in out.values()) == n_shards
        assert sorted(cluster.directory.shard_keys()) == [key]


# --------------------------------------------------- predictor regressions
class TestPredictorRegressions:
    def test_oneshot_flood_cannot_flush_live_streams(self):
        """[bugfix] cap-eviction used to take the stalest record outright,
        so a scan flood of never-returning keys flushed established gap
        history; one-shot records must go first."""
        p = NextUsePredictor(clock=lambda: 0.0, max_keys=8)
        hot = ModelKey("jax", "hot", "1")
        for t in (0.0, 1.0, 2.0, 3.0):  # an established stream, oldest
            p.record(hot, now=t)
        for i in range(50):             # newer one-shot scan keys
            p.record(ModelKey("jax", f"scan{i}", "1"), now=10.0 + i)
        st = p.stats()
        assert st["keys"] == 8
        assert st["evicted_streams"] == 0
        # the stream survived with its gap history intact
        assert p.predict_next_use_s(hot, now=3.0) == pytest.approx(1.0,
                                                                   rel=0.3)

    def test_stream_eviction_counted_when_unavoidable(self):
        p = NextUsePredictor(clock=lambda: 0.0, max_keys=4)
        for i in range(5):  # every record is a real stream: one must go
            k = ModelKey("jax", f"s{i}", "1")
            p.record(k, now=float(i))
            p.record(k, now=float(i) + 0.5)
        st = p.stats()
        assert st["keys"] == 4
        assert st["evicted_streams"] == 1

    def test_drop_model_forgets_predictor_stream(self, tmp_path):
        disk = DiskStore(str(tmp_path / "disk"))
        key = ModelKey("jax", "m", "1")
        disk.put(key, _tensors(nbytes=1 * MB))
        mrm = _mrm(disk, policy="slo")
        mrm.close(mrm.open(key))
        assert mrm.slo.predictor.stats()["keys"] >= 1
        out = mrm.drop_model(key)
        assert out["host"] or out["device"]
        assert mrm.host.peek(key) is None and mrm.device.peek(key) is None
        # history gone: the predictor no longer knows the key at all
        assert mrm.slo.predictor.predict_next_use_s(key) is None
        assert disk.contains(key)            # from_disk=False keeps the file

    def test_drop_model_skips_inuse_copies(self, tmp_path):
        disk = DiskStore(str(tmp_path / "disk"))
        key = ModelKey("jax", "m", "1")
        disk.put(key, _tensors(nbytes=1 * MB))
        mrm = _mrm(disk)
        h = mrm.open(key)
        out = mrm.drop_model(key)
        # the in-use device copy stays (and blocks the disk delete); the
        # idle host copy is fair game
        assert out["busy"] and not out["device"] and out["host"]
        assert mrm.device.peek(key) is not None
        mrm.close(h)
        out = mrm.drop_model(key, from_disk=True)
        assert out["device"] and out["disk"] and not out["busy"]
        assert not disk.contains(key)
