"""Prefix-KV sharing (beyond-paper: TrIMS applied to prefill results)."""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import DiskStore, MRM
from repro.models import init_params
from repro.serving import InferenceEngine, publish_model
from repro.serving.prefix_cache import PrefixKVStore, prompt_key


@pytest.fixture(scope="module")
def engine(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("prefix")
    disk = DiskStore(str(tmp / "models"))
    cfg = get_config("olmo-1b").reduced().replace(n_layers=2)
    publish_model(disk, cfg, init_params(cfg, jax.random.PRNGKey(0)),
                  name="olmo-1b")
    return InferenceEngine(disk, MRM(disk, device_capacity=1 << 30),
                           prefix_cache_bytes=256 << 20)


def test_same_prompt_skips_prefill_and_matches(engine):
    toks = np.arange(1, 17, dtype=np.int32)[None, :]
    out1, _ = engine.generate("olmo-1b", toks, max_new_tokens=4)
    assert engine.prefix_kv.misses == 1
    out2, _ = engine.generate("olmo-1b", toks, max_new_tokens=4)
    assert engine.prefix_kv.hits == 1
    np.testing.assert_array_equal(out1, out2)   # shared prefill, same result


def test_different_prompt_misses(engine):
    toks = np.arange(20, 36, dtype=np.int32)[None, :]
    engine.generate("olmo-1b", toks, max_new_tokens=2)
    assert engine.prefix_kv.misses >= 2


def test_shared_cache_not_mutated_by_decodes(engine):
    """Two decodes from one shared prefill must not interfere (functional
    purity = the isolation guarantee)."""
    toks = np.arange(40, 56, dtype=np.int32)[None, :]
    out_a, _ = engine.generate("olmo-1b", toks, max_new_tokens=6)
    key = [k for k in engine.prefix_kv.tier.entries if "olmo" in k][-1]
    snap = jax.tree.map(lambda x: np.asarray(x).copy(),
                        engine.prefix_kv.tier.entries[key].payload[1])
    out_b, _ = engine.generate("olmo-1b", toks, max_new_tokens=6)
    np.testing.assert_array_equal(out_a, out_b)
    after = engine.prefix_kv.tier.entries[key].payload[1]
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(after)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_store_capacity_eviction():
    store = PrefixKVStore(capacity_bytes=100)
    big = {"k": jax.numpy.zeros((10, 4), jax.numpy.float32)}  # 160B > 100
    store.insert("a", None, big)
    assert store.lookup("a") is None  # larger than tier: served uncached
    small = {"k": jax.numpy.zeros((5,), jax.numpy.float32)}   # 20B
    store.insert("b", None, small)
    store.insert("c", None, small)
    assert store.lookup("b") is not None
    assert store.lookup("c") is not None


def test_prompt_key_distinct():
    t1 = np.ones((1, 8), np.int32)
    t2 = np.ones((1, 8), np.int32)
    t3 = np.arange(8, dtype=np.int32)[None]
    assert prompt_key("m", t1, 16) == prompt_key("m", t2, 16)
    assert prompt_key("m", t1, 16) != prompt_key("m", t3, 16)
    assert prompt_key("m", t1, 16) != prompt_key("m2", t1, 16)
    assert prompt_key("m", t1, 16) != prompt_key("m", t1, 32)
