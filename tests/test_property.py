"""Property-based tests (hypothesis) on system invariants.

Cache/MRM invariants:
  I1: used_bytes == sum of resident entry sizes and never exceeds capacity
  I2: refcounted entries are never evicted
  I3: refcounts never go negative; open/close is balanced
  I4: whatever the op sequence, a model's bytes read back unchanged

Numerics invariants:
  N1: chunked SSD == sequential-scan SSD oracle for any chunking
  N2: MoE ragged and capacity paths agree when capacity is sufficient
  N3: router combine weights sum to 1
  N4: rho decision monotonicity
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core import LRU, LCU, MRM, ModelKey, Tier, TierCache, DiskStore, rho
from repro.core.cache import CapacityError
from repro.core.sharing import SharingConstants

MB = 1 << 20


# ---------------------------------------------------------------- cache ops
@st.composite
def cache_ops(draw):
    n_keys = draw(st.integers(2, 6))
    ops = draw(st.lists(st.tuples(
        st.sampled_from(["open", "close"]),
        st.integers(0, n_keys - 1)), min_size=1, max_size=40))
    sizes = draw(st.lists(st.integers(1, 8), min_size=n_keys, max_size=n_keys))
    return ops, sizes


@given(cache_ops(), st.sampled_from(["lru", "lcu", "fifo", "largest", "slo"]))
@settings(max_examples=60, deadline=None)
def test_tier_cache_invariants(ops_sizes, policy):
    ops, sizes = ops_sizes
    cap = 16
    c = TierCache(Tier.DEVICE, cap, policy)
    refs = {}
    for op, k in ops:
        key = f"m{k}"
        if op == "open":
            e = c.peek(key)
            if e is None:
                try:
                    c.make_room(sizes[k])
                except CapacityError:
                    continue
                e = c.insert(key, sizes[k])
            e.refcount += 1
            refs[key] = refs.get(key, 0) + 1
        else:
            e = c.peek(key)
            if e is not None and e.refcount > 0:
                e.refcount -= 1
                refs[key] -= 1
        # I1
        assert c.used == sum(e.nbytes for e in c.entries.values())
        assert c.used <= cap
        # I2: referenced entries still resident
        for kk, r in refs.items():
            if r > 0:
                assert c.peek(kk) is not None
        # I3
        assert all(e.refcount >= 0 for e in c.entries.values())


@given(st.lists(st.tuples(st.sampled_from(["open", "close"]),
                          st.integers(0, 3)), min_size=1, max_size=24),
       st.sampled_from(["lru", "lcu", "slo"]))
@settings(max_examples=20, deadline=None)
def test_mrm_random_open_close(tmp_path_factory, ops, policy):
    tmp = tmp_path_factory.mktemp("mrm")
    disk = DiskStore(str(tmp / "d"))
    expect = {}
    for k in range(4):
        t = {f"w{j}": np.full((1024,), k * 10 + j, np.float32) for j in range(3)}
        disk.put(ModelKey("jax", f"m{k}"), t)
        expect[k] = t
    mrm = MRM(disk, device_capacity=40 * 1024, host_capacity=200 * 1024,
              policy=policy)
    open_handles = {}
    for op, k in ops:
        key = ModelKey("jax", f"m{k}")
        if op == "open":
            try:
                h = mrm.open(key)
            except CapacityError:
                continue
            open_handles.setdefault(k, []).append(h)
            # I4: contents always correct regardless of tier transitions
            np.testing.assert_array_equal(np.asarray(h.weights["w1"]),
                                          expect[k]["w1"])
        elif open_handles.get(k):
            mrm.close(open_handles[k].pop())
        # invariants
        assert mrm.device.used <= mrm.device.capacity
        assert mrm.host.used <= mrm.host.capacity
        for kk, hs in open_handles.items():
            if hs:
                assert mrm.resident(ModelKey("jax", f"m{kk}"), Tier.DEVICE)
    for hs in open_handles.values():
        for h in hs:
            mrm.close(h)
    assert all(e.refcount == 0 for e in mrm.device.entries.values())


# ---------------------------------------------------------------- CostAware
@given(st.lists(st.tuples(st.integers(1, 8),      # entry size
                          st.integers(0, 30),     # arrivals recorded
                          st.integers(1, 40)),    # inter-arrival gap (x10ms)
                min_size=1, max_size=8),
       st.floats(0.01, 2.0))
@settings(max_examples=60, deadline=None)
def test_costaware_victims_first_ordering(specs, horizon):
    """CostAware.order is victims-first: ascending in the policy's own
    score (expected reload cost x reuse probability per byte), and a
    permutation of its input — for ANY mix of seen/unseen keys."""
    from repro.core.cache import CacheEntry, CostAware
    from repro.core.slo import NextUsePredictor
    now = 1000.0
    clock = [now]
    pred = NextUsePredictor(clock=lambda: clock[0])
    entries = []
    for i, (size, n_arrivals, gap_ds) in enumerate(specs):
        key, gap = f"m{i}", gap_ds * 0.01
        t = now - n_arrivals * gap
        for _ in range(n_arrivals):
            pred.record(key, now=t)
            t += gap
        e = CacheEntry(key=key, nbytes=size)
        e.last_used = now - gap
        entries.append(e)
    pol = CostAware(pred, horizon_fn=lambda: horizon)
    ordered = pol.order(list(entries))
    assert sorted(e.key for e in ordered) == sorted(e.key for e in entries)
    scores = [pol.score(e, now) for e in ordered]
    assert scores == sorted(scores)
    assert all(s >= 0.0 for s in scores)


# ---------------------------------------------------------------- SSD
@given(st.integers(1, 2), st.sampled_from([8, 16, 32]),
       st.sampled_from([4, 8, 16]), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_ssd_chunked_matches_reference(b, seqlen, chunk, seed):
    from repro.models.mamba import ssd_chunked, ssd_reference
    H, P, N = 2, 4, 8
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, seqlen, H, P)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, seqlen, H)), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.standard_normal((H,)), jnp.float32))
    Bm = jnp.asarray(rng.standard_normal((b, seqlen, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, seqlen, N)), jnp.float32)
    y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    y2, s2 = ssd_reference(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- MoE
@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_moe_ragged_vs_capacity(seed):
    from repro.configs import get_config
    from repro.models.moe import apply_moe, init_moe, router_topk
    cfg = get_config("qwen3-moe-30b-a3b").reduced().replace(
        n_experts=4, top_k=2, capacity_factor=4.0)  # capacity ample: no drops
    p = init_moe(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, cfg.d_model),
                          jnp.float32)
    out_r, aux_r = apply_moe(cfg.replace(moe_impl="ragged"), p, x)
    out_c, aux_c = apply_moe(cfg.replace(moe_impl="capacity"), p, x)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_c),
                               rtol=2e-2, atol=2e-3)
    np.testing.assert_allclose(float(aux_r), float(aux_c), rtol=1e-5)

    # N3: router weights
    topw, topi, aux = router_topk(cfg, p, x.reshape(-1, cfg.d_model))
    np.testing.assert_allclose(np.asarray(jnp.sum(topw, -1), np.float32),
                               1.0, rtol=1e-3)
    assert float(aux) >= 1.0 - 1e-3  # aux lower bound at perfect balance


# ---------------------------------------------------------------- rho
@given(st.integers(1, 1 << 34), st.integers(1, 4096),
       st.floats(1e-6, 1e-2), st.floats(1e-7, 1e-3), st.floats(1e6, 1e10))
@settings(max_examples=100, deadline=None)
def test_rho_properties(b, n, o, s, q):
    c = SharingConstants(o=o, s=s, q=q)
    # monotone increasing in b, decreasing in n
    assert rho(b + 1024, n, c) >= rho(b, n, c)
    assert rho(b, n + 1, c) <= rho(b, n, c)
    # exact formula
    np.testing.assert_allclose(rho(b, n, c), b / q - n * (o + s), rtol=1e-12)
