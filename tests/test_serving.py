"""Serving engine: publish/load roundtrip, cold-vs-warm, executable cache,
decode correctness through the engine, concurrent workers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DiskStore, MRM
from repro.models import forward, greedy_generate, init_params
from repro.serving import (InferenceEngine, Request, ServingWorkers,
                           arch_signature, publish_model)


@pytest.fixture(scope="module")
def served(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("serving")
    disk = DiskStore(str(tmp / "models"))
    cfg = get_config("olmo-1b").reduced().replace(n_layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    publish_model(disk, cfg, params, name="olmo-1b")
    # a second model with the same topology but different weights
    params2 = init_params(cfg, jax.random.PRNGKey(1))
    publish_model(disk, cfg, params2, name="olmo-1b-finetune")
    return disk, cfg, params


def test_publish_load_roundtrip(served):
    disk, cfg, params = served
    mrm = MRM(disk, device_capacity=1 << 30, host_capacity=1 << 30)
    engine = InferenceEngine(disk, mrm)
    sm, _ = engine.load_model("olmo-1b")
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(sm.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert sm.cfg == cfg
    engine.release(sm)


def test_generate_matches_reference(served):
    disk, cfg, params = served
    engine = InferenceEngine(disk, MRM(disk, device_capacity=1 << 30))
    toks = np.arange(1, 17, dtype=np.int32).reshape(1, 16) % cfg.vocab_size
    out, st = engine.generate("olmo-1b", toks, max_new_tokens=4)
    ref = greedy_generate(cfg, params, {"tokens": jnp.asarray(toks)}, 4, 16 + 4)
    np.testing.assert_array_equal(out, np.asarray(ref))


def test_warm_path_faster_and_shared(served):
    disk, cfg, _ = served
    mrm = MRM(disk, device_capacity=1 << 30)
    engine = InferenceEngine(disk, mrm)
    toks = np.ones((1, 8), np.int32)
    _, cold = engine.generate("olmo-1b", toks, max_new_tokens=2)
    _, warm = engine.generate("olmo-1b", toks, max_new_tokens=2)
    assert warm.tier_hit == "device"
    assert warm.model_load_s <= cold.model_load_s
    assert mrm.stats()["disk_loads"] == 1


def test_executable_cache_shared_across_same_topology(served):
    """Two same-architecture models share one compiled program — the
    compilation analogue of weight sharing (DESIGN.md §2)."""
    disk, cfg, _ = served
    engine = InferenceEngine(disk, MRM(disk, device_capacity=1 << 30))
    toks = np.ones((1, 8), np.int32)
    engine.generate("olmo-1b", toks, max_new_tokens=2)
    misses_before = engine.exe_cache_misses
    engine.generate("olmo-1b-finetune", toks, max_new_tokens=2)
    assert engine.exe_cache_misses == misses_before  # no new compile
    assert engine.exe_cache_hits >= 2


def test_no_trims_baseline_reloads(served):
    disk, cfg, _ = served
    engine = InferenceEngine(disk, mrm=None, use_trims=False)
    toks = np.ones((1, 8), np.int32)
    _, s1 = engine.generate("olmo-1b", toks, max_new_tokens=2)
    _, s2 = engine.generate("olmo-1b", toks, max_new_tokens=2)
    assert s1.tier_hit == "none(cold)" and s2.tier_hit == "none(cold)"


def test_concurrent_workers(served):
    disk, cfg, _ = served
    engine = InferenceEngine(disk, MRM(disk, device_capacity=1 << 30))
    workers = ServingWorkers(engine, n_workers=3)
    toks = np.ones((1, 8), np.int32)
    reqs = [workers.submit(Request(model="olmo-1b", tokens=toks, max_new=2))
            for _ in range(6)]
    workers.drain(reqs, timeout=120)
    workers.stop()
    assert all(not isinstance(r.result, Exception) for r in reqs)
    assert engine.mrm.stats()["disk_loads"] == 1  # one load served them all


def test_arch_signature_stable():
    c1 = get_config("olmo-1b").reduced()
    c2 = get_config("olmo-1b").reduced()
    c3 = c1.replace(n_layers=3)
    assert arch_signature(c1) == arch_signature(c2)
    assert arch_signature(c1) != arch_signature(c3)
