"""Sharded manifests + collective multi-source staging (DESIGN.md §8).

Covers the ObjectStore shard table (put-side splitter, per-shard fetch,
gc/dedup), the gather cost model, the ClusterNode gather path (scatter,
multi-peer gather, partial residency routing), and the fault-injection
regressions: corrupt/stale shard sources fall back to CLOUD without
aborting the gather, concurrent gathers coalesce onto one set of shard
fetches, and a node dropped mid-fetch is never charged as a live link
(source plans re-validate against the directory generation).
"""
import hashlib
import os
import threading

import numpy as np
import pytest

from repro.core import (Cluster, DiskStore, FaaSPlatform, HardwareModel,
                        MRM, ModelKey, ObjectStore, Router, Tier)
from repro.core.mrm import OpenTimings

MB = 1 << 20
SHARD = 256 << 10  # keep proxy files small; the decisive legs are modeled


def _tensors(nbytes=2 * MB, n=8, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32)
            for i in range(n)}


def _mrm(disk, dev=64 * MB, host=256 * MB, **kw):
    return MRM(disk, device_capacity=dev, host_capacity=host,
               hw=kw.pop("hw", HardwareModel()), **kw)


@pytest.fixture
def objstore(tmp_path):
    return ObjectStore(str(tmp_path / "cloud"), shard_bytes=SHARD)


def _cluster(tmp_path, objstore, n=3, populate=(), **mrm_kw):
    for key, seed in populate:
        objstore.put(key, _tensors(seed=seed))
    cluster = Cluster(objectstore=objstore)
    for i in range(n):
        cluster.add_node(f"node{i}",
                         _mrm(DiskStore(str(tmp_path / f"disk{i}")), **mrm_kw))
    return cluster


# --------------------------------------------------------- sharded ObjectStore
class TestShardedObjectStore:
    def test_put_records_shard_table(self, objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors())
        st = objstore.stat(key)
        shards = st["shards"]
        assert st["shard_bytes"] == SHARD
        assert len(shards) == -(-st["nbytes"] // SHARD)  # ceil division
        assert [s["index"] for s in shards] == list(range(len(shards)))
        assert sum(s["nbytes"] for s in shards) == st["nbytes"]
        assert all(s["nbytes"] == SHARD for s in shards[:-1])

    def test_whole_digest_addresses_uncompressed_content(self, tmp_path,
                                                         objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors())
        dest = DiskStore(str(tmp_path / "d"))
        objstore.fetch(key, dest)
        with open(dest.path_for(key), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == \
                objstore.stat(key)["digest"]

    def test_fetch_reassembles_sharded_entry(self, tmp_path, objstore):
        key = ModelKey("jax", "m", "1")
        tensors = _tensors(seed=3)
        objstore.put(key, tensors)
        dest = DiskStore(str(tmp_path / "d"))
        modeled, nbytes = objstore.fetch(key, dest)
        assert modeled > 0 and nbytes == objstore.stat(key)["nbytes"]
        got = dest.open(key).read_all(verify=True)
        np.testing.assert_array_equal(got["w3"], tensors["w3"])

    def test_sharded_compressed_roundtrip(self, tmp_path):
        obj = ObjectStore(str(tmp_path / "cloud"), shard_bytes=SHARD,
                          codec="zlib")
        key = ModelKey("jax", "m", "1")
        # compressible content: zeros
        tensors = {"w": np.zeros(MB // 4, np.float32)}
        obj.put(key, tensors)
        st = obj.stat(key)
        assert st["stored_nbytes"] < st["nbytes"]
        assert all(s["codec"] == "zlib" for s in st["shards"])
        dest = DiskStore(str(tmp_path / "d"))
        obj.fetch(key, dest)
        got = dest.open(key).read_all(verify=True)
        np.testing.assert_array_equal(got["w"], tensors["w"])

    def test_fetch_shard_verified_bytes(self, objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors(seed=1))
        st = objstore.stat(key)
        modeled, data = objstore.fetch_shard(key, 2)
        s = st["shards"][2]
        assert modeled > 0
        assert len(data) == s["nbytes"]
        assert hashlib.sha256(data).hexdigest() == s["digest"]
        assert objstore.stats()["shard_fetches"] == 1

    def test_fetch_shard_out_of_range_and_unsharded(self, objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors())
        with pytest.raises(KeyError):
            objstore.fetch_shard(key, 10_000)
        unsharded = ModelKey("jax", "plain", "1")
        objstore.put(unsharded, _tensors(seed=2), shard_bytes=0)
        assert objstore.shard_table(unsharded) == []
        with pytest.raises(KeyError):
            objstore.fetch_shard(unsharded, 0)
        with pytest.raises(KeyError):
            objstore.shard_table(ModelKey("jax", "nope"))

    def test_shard_dedup_across_versions(self, objstore):
        tensors = _tensors(seed=7)
        objstore.put(ModelKey("jax", "m", "1"), tensors)
        before = objstore.stats()["blobs"]
        objstore.put(ModelKey("jax", "m", "2"), tensors)
        st = objstore.stats()
        assert st["blobs"] == before  # every shard blob shared
        assert st["dedup_hits"] == before
        assert st["sharded_keys"] == 2

    def test_gc_keeps_live_shard_blobs(self, objstore):
        a, b = ModelKey("jax", "a"), ModelKey("jax", "b")
        objstore.put(a, _tensors(seed=1))
        objstore.put(b, _tensors(seed=2))
        assert objstore.gc_blobs() == 0
        objstore.delete(b)
        reclaimed = objstore.gc_blobs()
        assert reclaimed > 0
        # a still fetchable after the sweep
        assert objstore.contains(a)

    def test_modeled_shard_fetch_consistent(self, objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors())
        per_shard = sum(objstore.modeled_shard_fetch_s(key, s["index"])
                        for s in objstore.shard_table(key))
        whole = objstore.modeled_fetch_s(key)
        # serial per-shard fetches pay the rtt once per shard; the whole
        # fetch pays it once — per-shard can never be cheaper
        assert per_shard >= whole

    def test_shard_bytes_true_means_default(self, tmp_path):
        """Regression: shard_bytes=True must mean DEFAULT_SHARD_BYTES on
        the per-put path too — bool is an int, and literally 1-byte
        shards would explode the blob dir."""
        from repro.core.costmodel import DEFAULT_SHARD_BYTES
        obj = ObjectStore(str(tmp_path / "cloud"), shard_bytes=True)
        assert obj.shard_bytes == DEFAULT_SHARD_BYTES
        key = ModelKey("jax", "m", "1")
        obj.put(key, _tensors(), shard_bytes=True)
        st = obj.stat(key)
        assert st["shard_bytes"] == DEFAULT_SHARD_BYTES
        assert len(st["shards"]) == 1  # 2 MiB model, 16 MiB shards

    def test_manifest_persists_shards_across_instances(self, objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors())
        reopened = ObjectStore(objstore.root)
        st = reopened.stat(key)
        assert len(st["shards"]) == len(objstore.shard_table(key))
        _, data = reopened.fetch_shard(key, 0)
        assert hashlib.sha256(data).hexdigest() == st["shards"][0]["digest"]


# -------------------------------------------------------------- gather model
class TestGatherCostModel:
    def test_empty_gather_is_free(self):
        assert HardwareModel().gather_time([], 0) == 0.0

    def test_slowest_source_bounds_the_gather(self):
        hw = HardwareModel(ingest_bw=1e12)
        assert hw.gather_time([0.2, 0.5, 0.1], 1 * MB) == 0.5

    def test_ingest_bandwidth_caps_parallel_links(self):
        hw = HardwareModel(ingest_bw=1e9)
        total = 1 << 30  # 1 GiB over 1 GB/s ingest >= 1.07s
        t = hw.gather_time([0.01, 0.01, 0.01], total)
        assert t == pytest.approx(total / 1e9)

    def test_local_shards_not_charged_to_ingest(self, tmp_path):
        """Regression: the ingest-bw floor must only charge bytes that
        cross the NIC — a node holding most shards locally plans a gather
        priced at the missing bytes, not the whole model."""
        key = ModelKey("jax", "big", "1")
        obj = ObjectStore(str(tmp_path / "cloud"), shard_bytes=SHARD)
        obj.put(key, _tensors(seed=0))
        # ingest so slow that charging the FULL model would dwarf every
        # single-source option and wrongly kill the gather
        hw = HardwareModel(ingest_bw=20e6)
        cluster = Cluster(objectstore=obj)
        for i in range(2):
            cluster.add_node(f"node{i}",
                             _mrm(DiskStore(str(tmp_path / f"d{i}")), hw=hw))
        n_shards = len(obj.shard_table(key))
        missing = n_shards - 1
        for s in obj.shard_table(key)[:missing]:
            _, data = obj.fetch_shard(key, s["index"])
            cluster.node("node0").store_shard(key, s["index"], data)
        _, data = obj.fetch_shard(key, n_shards - 1)
        cluster.node("node1").store_shard(key, n_shards - 1, data)
        n0 = cluster.node("node0")
        st = obj.stat(key)
        rows, modeled, _gen = n0.plan_shard_sources(key, st)
        wire = sum(r["nbytes"] for r in rows if r["source"] != "local")
        assert wire < st["nbytes"]
        assert modeled < st["nbytes"] / hw.ingest_bw  # not the full floor
        assert modeled >= wire / hw.ingest_bw

    def test_gather_beats_single_source_with_parallel_peers(self):
        """Three disk-capped peer links in parallel beat any one of them
        and the cloud link — the §8 headline inequality on pure model."""
        hw = HardwareModel()
        nbytes = 64 * MB
        single_peer = hw.peer_fetch_time(nbytes, peer_disk=True)
        single_cloud = hw.cloud_fetch_time(nbytes)
        per_source = [hw.peer_fetch_time(nbytes // 3, peer_disk=True)] * 3
        gather = hw.gather_time(per_source, nbytes)
        assert gather < min(single_peer, single_cloud)


# -------------------------------------------------------- gather via cluster
class TestGather:
    def test_scatter_round_robins_shards(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        cluster = _cluster(tmp_path, objstore, populate=[(key, 0)])
        placement = cluster.scatter(key, node_names=["node1", "node2"])
        n_shards = len(objstore.shard_table(key))
        assert sorted(i for ids in placement.values() for i in ids) \
            == list(range(n_shards))
        n1 = cluster.node("node1")
        assert n1.local_shards(key) == placement["node1"]
        assert cluster.directory.shards_on(key, "node1") \
            == placement["node1"]
        assert 0 < n1.shard_fraction(key) < 1

    def test_gather_from_scattered_peers(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        tensors = _tensors(seed=0)
        objstore.put(key, tensors)
        cluster = _cluster(tmp_path, objstore, n=4)
        cluster.scatter(key, node_names=["node1", "node2", "node3"])
        n0 = cluster.node("node0")
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "gather"
        assert 0 < h.timings.gather_s < objstore.modeled_fetch_s(key)
        np.testing.assert_array_equal(np.asarray(h.weights["w0"]),
                                      tensors["w0"])
        stats = n0.stats()
        assert stats["gather_fetches"] == 1
        assert stats["shards_from_peers"] == len(objstore.shard_table(key))
        assert stats["gather_fallbacks"] == 0
        n0.mrm.close(h)

    def test_gather_splits_across_full_file_holders(self, tmp_path, objstore):
        """Two peers each holding the whole model: the plan balances the
        shards across both links and beats either single link."""
        key = ModelKey("jax", "big", "1")
        cluster = _cluster(tmp_path, objstore, populate=[(key, 0)])
        n0, n1, n2 = (cluster.node(f"node{i}") for i in range(3))
        for peer in (n1, n2):
            objstore.fetch(key, peer.mrm.disk)
            cluster.directory.publish(peer.name, key, Tier.DISK)
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "gather"
        assert h.timings.gather_s < n0.hw.peer_fetch_time(
            objstore.nbytes(key), peer_disk=True)
        assert n1.stats()["shard_serves"] > 0
        assert n2.stats()["shard_serves"] > 0
        n0.mrm.close(h)

    def test_gather_declined_with_single_source(self, tmp_path, objstore):
        """One full-file peer: shard-by-shard over the same single link
        cannot beat the whole-file transfer — the plain peer path runs."""
        key = ModelKey("jax", "big", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(key, 0)])
        n0, n1 = cluster.node("node0"), cluster.node("node1")
        objstore.fetch(key, n1.mrm.disk)
        cluster.directory.publish("node1", key, Tier.DISK)
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "peer"
        assert h.timings.gather_s == 0.0
        assert n0.stats()["gather_fetches"] == 0
        assert n0.stats()["peer_fetches"] == 1
        n0.mrm.close(h)

    def test_local_shards_are_free_sources(self, tmp_path, objstore):
        """Shards already in the local cache are not re-fetched, and the
        full assembled copy supersedes (and clears) the local shard
        cache."""
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=3)
        # node0 itself holds a third of the shards; node1/node2 the rest
        cluster.scatter(key, node_names=["node0", "node1", "node2"])
        n0 = cluster.node("node0")
        mine = list(n0.local_shards(key))
        assert mine
        h = n0.mrm.open(key)
        stats = n0.stats()
        assert h.timings.tier_hit == "gather"
        assert stats["shards_local"] == len(mine)
        assert stats["shards_from_peers"] \
            == len(objstore.shard_table(key)) - len(mine)
        # full copy supersedes the shard cache
        assert n0.local_shards(key) == []
        assert cluster.directory.shards_on(key, "node0") == []
        n0.mrm.close(h)

    def test_gather_publishes_disk_and_warms(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=3)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        h = n0.mrm.open(key)
        assert cluster.directory.tier_on(key, "node0") == Tier.DEVICE
        assert n0.mrm.disk.contains(key)
        h2 = n0.mrm.open(key)
        assert h2.timings.tier_hit == "device"
        assert n0.stats()["gather_fetches"] == 1
        assert n0.mrm.metrics["gather_fetches"] == 1
        assert n0.mrm.metrics["modeled_fetch_s"] > 0
        n0.mrm.close(h)
        n0.mrm.close(h2)

    def test_gather_disabled_falls_back(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = Cluster(objectstore=objstore)
        for i in range(3):
            cluster.add_node(f"node{i}",
                             _mrm(DiskStore(str(tmp_path / f"d{i}"))),
                             gather=False)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        h = n0.mrm.open(key)
        # no gather: the scattered shards are unreachable as whole-model
        # sources, so the open pays the CLOUD leg
        assert h.timings.tier_hit == "cloud"
        assert n0.stats()["gather_fetches"] == 0
        n0.mrm.close(h)

    def test_gather_without_peer_fetch_declines(self, tmp_path, objstore):
        """peer_fetch=False leaves only the cloud link — a single-source
        gather cannot beat the whole-file cloud fetch, so it declines."""
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = Cluster(objectstore=objstore)
        cluster.add_node("node0", _mrm(DiskStore(str(tmp_path / "d0"))),
                         peer_fetch=False)
        cluster.add_node("node1", _mrm(DiskStore(str(tmp_path / "d1"))))
        cluster.scatter(key, node_names=["node1"])
        n0 = cluster.node("node0")
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "cloud"
        assert n0.stats()["gather_fetches"] == 0
        n0.mrm.close(h)

    def test_host_tier_gather_for_device_oversized_model(self, tmp_path,
                                                         objstore):
        """A model larger than the device tier still gathers: the open
        lands it host-resident (the paper's large-model case)."""
        key = ModelKey("jax", "big", "1")
        tensors = _tensors(seed=0)  # 2 MiB model
        objstore.put(key, tensors)
        cluster = Cluster(objectstore=objstore)
        for i in range(3):
            cluster.add_node(
                f"node{i}",
                _mrm(DiskStore(str(tmp_path / f"d{i}")), dev=1 * MB))
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        h = n0.mrm.open(key, tier="host")
        assert h.timings.tier_hit == "gather"
        assert n0.mrm.resident(key, Tier.HOST)
        np.testing.assert_array_equal(np.asarray(h.weights["w0"]),
                                      tensors["w0"])
        n0.mrm.close(h)


# ------------------------------------------------------------ fault injection
class TestGatherFaults:
    def test_corrupt_peer_falls_back_to_cloud(self, tmp_path, objstore):
        """A peer serving garbage fails the per-shard digest check; every
        affected shard transparently re-sources from CLOUD and the
        assembled file still verifies end-to-end."""
        key = ModelKey("jax", "big", "1")
        tensors = _tensors(seed=0)
        objstore.put(key, tensors)
        cluster = _cluster(tmp_path, objstore, n=3)
        n0, n1, n2 = (cluster.node(f"node{i}") for i in range(3))
        for peer in (n1, n2):
            objstore.fetch(key, peer.mrm.disk)
            cluster.directory.publish(peer.name, key, Tier.DISK)
        # size-preserving corruption of node1's copy (hints stay "valid")
        path = n1.mrm.disk.path_for(key)
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.write(b"\xff" * size)
        h = n0.mrm.open(key)
        stats = n0.stats()
        assert h.timings.tier_hit == "gather"
        assert stats["gather_fallbacks"] > 0
        assert stats["shards_from_cloud"] >= stats["gather_fallbacks"]
        np.testing.assert_array_equal(np.asarray(h.weights["w0"]),
                                      tensors["w0"])
        with open(n0.mrm.disk.path_for(key), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == \
                objstore.stat(key)["digest"]
        n0.mrm.close(h)

    def test_corrupt_shard_cache_falls_back(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        tensors = _tensors(seed=0)
        objstore.put(key, tensors)
        cluster = _cluster(tmp_path, objstore, n=3)
        cluster.scatter(key, node_names=["node1", "node2"])
        n1 = cluster.node("node1")
        bad = n1.local_shards(key)[0]
        with open(n1._shard_path(key, bad), "r+b") as f:
            f.write(b"\x00" * 64)
        n0 = cluster.node("node0")
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "gather"
        assert n0.stats()["gather_fallbacks"] >= 1
        assert n0.stats()["shards_from_cloud"] >= 1
        np.testing.assert_array_equal(np.asarray(h.weights["w1"]),
                                      tensors["w1"])
        n0.mrm.close(h)

    def test_corrupt_local_shard_evicted_from_cache(self, tmp_path,
                                                    objstore):
        """A corrupt local shard is not just skipped — its file and
        directory hint are dropped, so neither this node nor any planning
        peer keeps re-reading the bad copy."""
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=2)
        n0 = cluster.node("node0")
        cluster.scatter(key, node_names=["node0"])
        bad = n0.local_shards(key)[0]
        with open(n0._shard_path(key, bad), "r+b") as f:
            f.write(b"\x00" * 64)
        st = objstore.stat(key)
        row = {"index": bad, "offset": bad * st["shard_bytes"],
               "nbytes": st["shards"][bad]["nbytes"], "source": "local",
               "node": None, "modeled_s": 0.0}
        acct = {"loads": {}, "wire_bytes": 0}
        data = n0._fetch_one_shard(key, st, row,
                                   cluster.directory.generation, acct)
        assert hashlib.sha256(data).hexdigest() == \
            st["shards"][bad]["digest"]          # CLOUD supplied it
        assert not n0.has_shard(key, bad)        # bad copy unlinked
        assert bad not in cluster.directory.shards_on(key, "node0")
        assert acct["wire_bytes"] == st["shards"][bad]["nbytes"]
        assert n0.shard_fraction(key) < 1.0      # cache invalidated too

    def test_peer_dies_mid_gather(self, tmp_path, objstore, monkeypatch):
        """A peer dropped after the plan was made: the remaining shards
        planned onto it re-validate against the directory generation,
        re-plan onto CLOUD, and the assembly completes with the correct
        digest — without charging the dead link."""
        key = ModelKey("jax", "big", "1")
        tensors = _tensors(seed=0)
        objstore.put(key, tensors)
        cluster = _cluster(tmp_path, objstore, n=3)
        n0, n1, n2 = (cluster.node(f"node{i}") for i in range(3))
        for peer in (n1, n2):
            objstore.fetch(key, peer.mrm.disk)
            cluster.directory.publish(peer.name, key, Tier.DISK)
        real = n0._fetch_one_shard
        state = {"fetched": 0}

        def dying_fetch(k, st, row, plan_gen, loads):
            data = real(k, st, row, plan_gen, loads)
            state["fetched"] += 1
            if state["fetched"] == 1:
                cluster.directory.drop_node("node2")
            return data

        monkeypatch.setattr(n0, "_fetch_one_shard", dying_fetch)
        h = n0.mrm.open(key)
        stats = n0.stats()
        assert h.timings.tier_hit == "gather"
        assert stats["plan_replans"] >= 1       # dead link never charged
        assert stats["shards_from_cloud"] >= 1  # re-planned onto CLOUD
        np.testing.assert_array_equal(np.asarray(h.weights["w0"]),
                                      tensors["w0"])
        with open(n0.mrm.disk.path_for(key), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == \
                objstore.stat(key)["digest"]
        n0.mrm.close(h)

    def test_concurrent_gathers_coalesce(self, tmp_path, objstore,
                                         monkeypatch):
        """Two racing gathers of one key share one set of shard fetches
        (PR 3/PR 4 race-regression style: the second caller blocks on the
        first's in-flight gather instead of re-downloading)."""
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=3)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        started = threading.Event()
        real = n0._fetch_one_shard

        def slow_fetch(*a, **kw):
            started.set()
            return real(*a, **kw)

        monkeypatch.setattr(n0, "_fetch_one_shard", slow_fetch)
        results = {}

        def gather(tag):
            t = OpenTimings()
            results[tag] = (n0.fetch_for(key, t), t)

        t1 = threading.Thread(target=gather, args=("a",))
        t1.start()
        started.wait(timeout=30)  # the primary is inside its gather now
        t2 = threading.Thread(target=gather, args=("b",))
        t2.start()
        t1.join(timeout=30)
        t2.join(timeout=30)
        assert results["a"][0] and results["b"][0]
        stats = n0.stats()
        assert stats["gather_fetches"] == 1
        assert stats["gather_coalesced"] == 1
        n_shards = len(objstore.shard_table(key))
        assert stats["shards_from_peers"] + stats["shards_from_cloud"] \
            + stats["shards_local"] == n_shards

    def test_concurrent_opens_share_one_gather(self, tmp_path, objstore):
        """MRM-level coalescing already dedups opens; the gather beneath
        them runs once (no duplicated shard downloads)."""
        key = ModelKey("jax", "big", "1")
        tensors = _tensors(seed=0)
        objstore.put(key, tensors)
        cluster = _cluster(tmp_path, objstore, n=3)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        handles = [None] * 8
        errs = []

        def worker(i):
            try:
                handles[i] = n0.mrm.open(key)
            except BaseException as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(len(handles))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errs
        assert n0.stats()["gather_fetches"] == 1
        assert n0.mrm.metrics["disk_loads"] == 1
        for h in handles:
            np.testing.assert_array_equal(np.asarray(h.weights["w0"]),
                                          tensors["w0"])
            n0.mrm.close(h)


# --------------------------------------- drop_node mid-fetch (ride-along fix)
class TestDropNodeMidFetchRegression:
    def test_single_source_replan_on_drop(self, tmp_path, objstore,
                                          monkeypatch):
        """Regression: drop_node during an in-flight peer fetch used to
        leave the fetcher charging the dead link. The plan now snapshots
        the directory generation and re-validates after the transfer —
        a vanished peer is never charged and the fetch re-plans (CLOUD
        here, since no other peer holds the model)."""
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors(seed=0), shard_bytes=0)  # unsharded
        cluster = _cluster(tmp_path, objstore, n=2)
        n0, n1 = cluster.node("node0"), cluster.node("node1")
        n1.mrm.close(n1.mrm.open(key))
        import repro.core.cluster as cluster_mod
        real_read = cluster_mod.ClusterNode.read_model

        def drop_mid_copy(self, key, write, **kw):
            out = real_read(self, key, write, **kw)
            cluster.directory.drop_node("node1")
            return out

        monkeypatch.setattr(cluster_mod.ClusterNode, "read_model",
                            drop_mid_copy)
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "cloud"
        assert h.timings.peer_s == 0.0          # dead link never charged
        assert n0.stats()["peer_fetches"] == 0
        assert n0.stats()["plan_replans"] == 1
        assert n0.mrm.metrics["cloud_downloads"] == 1
        n0.mrm.close(h)

    def test_peer_copy_vanishing_mid_transfer_replans(self, tmp_path,
                                                      objstore, monkeypatch):
        """A peer file deleted between planning and the copy is a stale
        hint, not an error: the fetch re-plans and falls through to
        CLOUD."""
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors(seed=0), shard_bytes=0)
        cluster = _cluster(tmp_path, objstore, n=2)
        n0, n1 = cluster.node("node0"), cluster.node("node1")
        n1.mrm.close(n1.mrm.open(key))
        import repro.core.cluster as cluster_mod
        peer_path = n1.mrm.disk.path_for(key)

        # only the peer data plane is faulted (the CLOUD leg never calls
        # the peer surface), mirroring a copy deleted under the serve
        def vanish(self, key, write, **kw):
            os.unlink(peer_path)
            raise FileNotFoundError(peer_path)

        monkeypatch.setattr(cluster_mod.ClusterNode, "read_model", vanish)
        h = n0.mrm.open(key)
        assert h.timings.tier_hit == "cloud"
        assert n0.stats()["peer_fetches"] == 0
        n0.mrm.close(h)

    def test_publish_after_drop_is_ignored(self, tmp_path, objstore):
        """Hints never resurrect a dropped node — whole-model or shard."""
        key = ModelKey("jax", "m", "1")
        cluster = _cluster(tmp_path, objstore, n=2, populate=[(key, 0)])
        cluster.directory.drop_node("node1")
        gen = cluster.directory.generation
        cluster.directory.publish("node1", key, Tier.DISK)
        cluster.directory.publish_shard("node1", key, 0, Tier.DISK)
        assert cluster.directory.holders(key) == []
        assert cluster.directory.shard_holders(key, 0) == []
        assert cluster.directory.generation == gen


# --------------------------------------------------- partial residency routing
class TestPartialResidencyRouting:
    def _platforms(self, cluster):
        nodes = []
        for name, cn in cluster.nodes.items():
            p = FaaSPlatform(cn.mrm, name=name, cluster_node=cn)
            p.deploy("f", lambda ctx, pl: ctx.load_model(*pl).nbytes,
                     prewarm=False)
            nodes.append(p)
        return nodes

    def test_residency_grades(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=3)
        cluster.scatter(key, node_names=["node1"])
        platforms = {p.name: p for p in self._platforms(cluster)}
        assert platforms["node0"].residency(key) == 0.0
        # node1 holds every shard but no assembled copy: DISK-weighted 1.0
        assert platforms["node1"].residency(key) == pytest.approx(
            Tier.DISK.warmth)
        objstore.fetch(key, cluster.node("node2").mrm.disk)
        assert platforms["node2"].residency(key) == Tier.DISK.warmth
        h = cluster.node("node2").mrm.open(key)
        assert platforms["node2"].residency(key) == Tier.DEVICE.warmth
        cluster.node("node2").mrm.close(h)

    def test_router_prefers_partial_holder(self, tmp_path, objstore):
        """No node holds the model whole; the router steers to the node
        with the largest fraction of shard bytes instead of treating all
        of them as equally cold."""
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=3)
        n_shards = len(objstore.shard_table(key))
        most = list(range(n_shards - 1))
        for i in most:
            _, data = objstore.fetch_shard(key, i)
            cluster.node("node1").store_shard(key, i, data)
        _, data = objstore.fetch_shard(key, n_shards - 1)
        cluster.node("node2").store_shard(key, n_shards - 1, data)
        platforms = self._platforms(cluster)
        router = Router(platforms)
        chosen = router.route("f", [key])
        assert chosen.name == "node1"

    def test_full_copy_outranks_partial(self, tmp_path, objstore):
        key = ModelKey("jax", "big", "1")
        objstore.put(key, _tensors(seed=0))
        cluster = _cluster(tmp_path, objstore, n=3)
        cluster.scatter(key, node_names=["node1"])       # all shards
        objstore.fetch(key, cluster.node("node2").mrm.disk)  # full copy
        platforms = self._platforms(cluster)
        router = Router(platforms)
        # full-disk 1.0 ties shard-complete 1.0 — warm node2 to break it
        cluster.node("node2").mrm.close(cluster.node("node2").mrm.open(key))
        assert router.route("f", [key]).name == "node2"

    def test_warmth_unchanged_for_unsharded(self, tmp_path, objstore):
        key = ModelKey("jax", "m", "1")
        objstore.put(key, _tensors(seed=0), shard_bytes=0)
        cluster = _cluster(tmp_path, objstore, n=2)
        platforms = self._platforms(cluster)
        assert platforms[0].residency(key) == 0.0
        h = cluster.node("node0").mrm.open(key)
        assert platforms[0].residency(key) == Tier.DEVICE.warmth
        cluster.node("node0").mrm.close(h)
