"""Unit tests for the sharding rule tables (no devices needed: specs only)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.mesh import batch_axes
from repro.launch.specs import batch_struct, cache_struct, params_struct
from repro.configs.base import SHAPES_BY_NAME


class FakeMesh:
    """Duck-typed mesh: axis names + shape mapping (enough for spec rules)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


SP = FakeMesh({"data": 16, "model": 16})
MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _find(specs, *path):
    node = specs
    for k in path:
        node = node[k]
    return node


def test_dense_param_specs_single_pod():
    cfg = get_config("deepseek-7b")
    specs = shd.make_param_specs(cfg, params_struct(cfg), SP)
    # embedding: vocab->model, d->fsdp
    assert _find(specs, "embed") == P("model", "data")
    # stacked attention weights: (L, D, qd) -> (None, fsdp, tensor)
    assert _find(specs, "layers", "attn", "wq") == P(None, "data", "model")
    assert _find(specs, "layers", "attn", "wo") == P(None, "model", "data")
    assert _find(specs, "layers", "ffn", "w_down") == P(None, "model", "data")
    # norms replicate
    assert _find(specs, "layers", "ln1", "scale") == P(None, None)  # stacked


def test_dense_param_specs_multi_pod_fsdp_tuple():
    cfg = get_config("deepseek-7b")
    specs = shd.make_param_specs(cfg, params_struct(cfg), MP)
    assert _find(specs, "layers", "attn", "wq") == P(None, ("pod", "data"), "model")


def test_moe_expert_specs():
    cfg = get_config("qwen3-moe-30b-a3b")
    specs = shd.make_param_specs(cfg, params_struct(cfg), SP)
    # routed experts (L, E, D, F): E->model, F->fsdp
    assert _find(specs, "layers", "ffn", "w_gate") == P(None, "model", None, "data")
    assert _find(specs, "layers", "ffn", "w_down") == P(None, "model", "data", None)
    assert _find(specs, "layers", "ffn", "router") == P(None, "data", None)
    # dense mlp rule NOT applied to expert tensors and vice versa
    dense = get_config("deepseek-7b")
    dspecs = shd.make_param_specs(dense, params_struct(dense), SP)
    assert _find(dspecs, "layers", "ffn", "w_gate") == P(None, "data", "model")


def test_non_divisible_dims_replicate():
    # mamba2: vocab 50280 not divisible by 16 -> padded table IS divisible;
    # A_log (nh,) replicates by rule
    cfg = get_config("mamba2-370m")
    specs = shd.make_param_specs(cfg, params_struct(cfg), SP)
    assert _find(specs, "layers", "mamba", "A_log") == P(None, None)  # stacked
    assert cfg.padded_vocab % 16 == 0
    assert _find(specs, "embed") == P("model", "data")


def test_cache_specs_decode():
    cfg = get_config("qwen1.5-110b")
    cs = cache_struct(cfg, SHAPES_BY_NAME["decode_32k"])
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: shd.cache_spec(p, l, SP, 128), cs)
    # (L, B, T, K, hd): B=128 -> data; kv=8 not divisible by 16 -> hd->model
    assert specs["attn"]["k"] == P(None, "data", None, None, "model")


def test_cache_specs_long_context_batch1():
    cfg = get_config("jamba-1.5-large-398b")
    cs = cache_struct(cfg, SHAPES_BY_NAME["long_500k"])
    specs = jax.tree_util.tree_map_with_path(
        lambda p, l: shd.cache_spec(p, l, SP, 1), cs)
    # batch=1 not shardable -> sequence dim takes the fsdp axis
    k = specs["attn"]["k"]
    assert k[2] == "data"            # 524288 % 16 == 0
    # ssm states: heads on model
    assert specs["mamba"]["ssm"][-3] == "model"


def test_batch_spec():
    assert shd.batch_spec(SP) == P("data")
    assert shd.batch_spec(MP) == P(("pod", "data"))
