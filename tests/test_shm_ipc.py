"""Cross-process TrIMS: msgpack/unix-socket control plane + shm data plane.

Subprocess clients attach the MRM's shared-memory segments and validate
tensor contents — the host-tier analogue of CUDA-IPC sharing (DESIGN.md §2).
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import DiskStore, MRM, ModelKey
from repro.core.shm_ipc import MRMServer, RemoteTrimsClient

MB = 1 << 20


def _tensors(nbytes=2 * MB, n=4, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32) for i in range(n)}


@pytest.fixture
def server(tmp_path):
    disk = DiskStore(str(tmp_path / "disk"))
    disk.put(ModelKey("jax", "shared"), _tensors(seed=7))
    mrm = MRM(disk, device_capacity=64 * MB, host_capacity=256 * MB, use_shm=True)
    srv = MRMServer(mrm, str(tmp_path / "mrm.sock"))
    yield srv
    srv.stop()
    # release host-tier shm
    for e in list(mrm.host.entries.values()):
        if e.payload is not None:
            e.payload.release()


def test_same_process_client(server):
    client = RemoteTrimsClient(server.sock_path)
    h = client.open("jax", "shared")
    expect = _tensors(seed=7)
    for k, v in expect.items():
        np.testing.assert_array_equal(h.arrays[k], v)
    assert h.timings["tier_hit"] in ("disk", "host")
    h2 = client.open("jax", "shared")
    assert h2.timings["tier_hit"] == "host"      # warm
    assert h2.timings["total_s"] < h.timings["total_s"] + 1e-3
    client.close(h)
    client.close(h2)
    stats = client.stats()
    assert stats["disk_loads"] == 1
    client.disconnect()


CLIENT_SCRIPT = textwrap.dedent("""
    import json, sys, time
    import numpy as np
    sys.path.insert(0, {src!r})
    from repro.core.shm_ipc import RemoteTrimsClient

    c = RemoteTrimsClient({sock!r})
    t0 = time.perf_counter()
    h = c.open("jax", "shared")
    open_s = time.perf_counter() - t0
    checksum = float(sum(float(np.asarray(a, np.float64).sum()) for a in h.arrays.values()))
    out = {{"checksum": checksum, "tier": h.timings["tier_hit"],
           "open_s": open_s, "attach_s": h.attach_s, "nbytes": h.nbytes}}
    c.close(h)
    c.disconnect()
    print(json.dumps(out))
""")


def test_cross_process_sharing(server, tmp_path):
    """Two OS processes open the same model: one load, shared bytes."""
    script = CLIENT_SCRIPT.format(src="src", sock=server.sock_path)
    results = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", script], cwd="/root/repo",
                             capture_output=True, text=True, timeout=60)
        assert out.returncode == 0, out.stderr
        results.append(json.loads(out.stdout.strip().splitlines()[-1]))

    expect = _tensors(seed=7)
    want = float(sum(np.asarray(a, np.float64).sum() for a in expect.values()))
    for r in results:
        assert abs(r["checksum"] - want) < 1e-3
    # exactly one deserialization served both processes
    assert server.mrm.metrics["disk_loads"] == 1
    assert results[1]["tier"] == "host"
    # after both clients closed, refcount is back to zero
    key = ModelKey("jax", "shared")
    assert server.mrm.host.peek(key).refcount == 0


def test_connection_death_releases_handles(server):
    client = RemoteTrimsClient(server.sock_path)
    h = client.open("jax", "shared")
    key = ModelKey("jax", "shared")
    assert server.mrm.host.peek(key).refcount == 1
    client.disconnect()   # no clean close
    import time
    for _ in range(50):
        if server.mrm.host.peek(key).refcount == 0:
            break
        time.sleep(0.05)
    assert server.mrm.host.peek(key).refcount == 0


def test_client_is_thread_safe(server):
    """Regression: RemoteTrimsClient shares ONE socket; unsynchronized
    threads used to interleave request/response frames and read each
    other's replies. The per-request lock must keep every thread's
    open/stats/close pairing intact under contention."""
    import threading

    client = RemoteTrimsClient(server.sock_path)
    expect = _tensors(seed=7)
    errs = []

    def worker(i):
        try:
            for _ in range(15):
                h = client.open("jax", "shared")
                assert h.timings["tier_hit"] in ("disk", "host")
                np.testing.assert_array_equal(h.arrays["w0"], expect["w0"])
                assert isinstance(client.stats()["opens"], int)
                client.close(h)
        except Exception as e:  # noqa: BLE001 — collected for the assert
            errs.append((i, e))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs[:3]
    # every handle closed: the shared entry's refcount drained to zero
    assert server.mrm.host.peek(ModelKey("jax", "shared")).refcount == 0
    client.disconnect()
