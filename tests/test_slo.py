"""SLO-aware eviction (core.slo + CostAware) and serving-path concurrency.

Covers the DESIGN.md §7 stack — predictor on synthetic arrival traces,
reload-cost pricing per backing tier, victims-first CostAware ordering,
MRM metrics wiring, deadline plumbing through FaaSPlatform/Router — plus
the concurrency fixes that rode along: accounting under the container
lock, bounded latency stats, thread-safe Router dispatch counts, and the
write-back worker's shutdown/error path.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (CostAware, DiskStore, FaaSPlatform, MRM, ModelKey,
                        NextUsePredictor, ReloadCostEstimator, Router, Tier,
                        TierCache, make_policy)
from repro.core.cache import CacheEntry
from repro.core.costmodel import HardwareModel
from repro.core.faas import LatencyStats

MB = 1 << 20


def _tensors(nbytes=1 * MB, n=2, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32)
            for i in range(n)}


# ------------------------------------------------------------- predictor
class TestNextUsePredictor:
    def test_ewma_gap_converges_on_periodic_trace(self):
        clock = [0.0]
        p = NextUsePredictor(clock=lambda: clock[0])
        for _ in range(20):
            p.record("k")
            clock[0] += 0.05
        assert p.mean_gap_s("k") == pytest.approx(0.05, rel=1e-6)
        # next use predicted one gap after the last arrival
        assert p.predict_next_use_s("k") == pytest.approx(0.0, abs=1e-9)
        clock[0] -= 0.03  # 0.02s after the last arrival
        assert p.predict_next_use_s("k") == pytest.approx(0.03, rel=1e-6)

    def test_hot_key_outranks_cold_key(self):
        clock = [0.0]
        p = NextUsePredictor(clock=lambda: clock[0])
        for i in range(100):
            p.record("hot")          # every tick
            if i % 10 == 0:
                p.record("cold")     # every 10 ticks
            clock[0] += 0.01
        hot = p.reuse_probability("hot", horizon_s=0.05)
        cold = p.reuse_probability("cold", horizon_s=0.05)
        assert hot > cold > 0.0

    def test_unseen_key_returns_none(self):
        p = NextUsePredictor()
        assert p.mean_gap_s("nope") is None
        assert p.predict_next_use_s("nope") is None
        assert p.reuse_probability("nope", 1.0) is None

    def test_dead_stream_fades_out(self):
        from repro.core.slo import OVERDUE_DECAY_GAPS
        clock = [0.0]
        p = NextUsePredictor(clock=lambda: clock[0])
        for _ in range(10):
            p.record("dead")
            clock[0] += 0.01
        fresh = p.reuse_probability("dead", horizon_s=0.1)
        # far past many multiples of the gap, the stream is presumed dead
        clock[0] += 0.01 * OVERDUE_DECAY_GAPS * 10
        stale = p.reuse_probability("dead", horizon_s=0.1)
        assert stale < fresh / 20

    def test_bounded_key_count_drops_stalest(self):
        clock = [0.0]
        p = NextUsePredictor(clock=lambda: clock[0], max_keys=8)
        for i in range(50):
            p.record(f"k{i}")
            clock[0] += 1.0
        assert len(p) == 8
        assert p.mean_gap_s("k0") is None       # stalest dropped
        assert p.arrivals("k49") == 1           # newest kept

    def test_single_arrival_uses_idle_time_as_gap(self):
        clock = [0.0]
        p = NextUsePredictor(clock=lambda: clock[0], default_gap_s=0.1)
        p.record("once")
        clock[0] += 5.0
        # one arrival, idle 5s: predicted next use ~5s out, low probability
        assert p.predict_next_use_s("once") <= 5.0
        assert p.reuse_probability("once", horizon_s=0.1) < 0.5


# ------------------------------------------------------- cost estimator
class TestReloadCostEstimator:
    def test_prices_rise_with_colder_backing_tier(self):
        hw = HardwareModel()
        tiers = {}
        est = ReloadCostEstimator(hw, lambda k, nb: tiers[k])
        nb = 64 * MB
        tiers.update(dev=Tier.DEVICE, host=Tier.HOST, disk=Tier.DISK,
                     cloud=None)
        c = {k: est.reload_cost_s(k, nb) for k in tiers}
        assert c["dev"] == 0.0
        assert c["dev"] < c["host"] < c["disk"] < c["cloud"]
        assert c["host"] == pytest.approx(hw.h2d_time(nb))
        assert c["disk"] == pytest.approx(hw.staging_pipelined_time(nb))


# ---------------------------------------------------- CostAware ordering
class TestCostAware:
    def _entry(self, key, nbytes, last_used):
        e = CacheEntry(key=key, nbytes=nbytes)
        e.last_used = last_used
        return e

    def test_victims_first_orders_by_cost_times_probability(self):
        clock = [100.0]
        pred = NextUsePredictor(clock=lambda: clock[0])
        t = 0.0
        while t < 100.0:  # hot: 10ms gaps; cold: 1s gaps
            pred.record("hot", now=t)
            t += 0.01
        t = 0.0
        while t < 100.0:
            pred.record("cold", now=t)
            t += 1.0
        costs = {"hot": 1.0, "cold": 1.0, "pricey-cold": 100.0}
        pred.record("pricey-cold", now=0.0)
        pred.record("pricey-cold", now=99.0)  # gap 99s: cold, but expensive
        pol = CostAware(pred, cost_fn=lambda e: costs[e.key],
                        horizon_fn=lambda: 0.1)
        entries = [self._entry("hot", MB, 99.99),
                   self._entry("cold", MB, 99.0),
                   self._entry("pricey-cold", MB, 99.0)]
        order = [e.key for e in pol.order(entries)]
        # cheapest expected loss evicted first; the hot entry is kept last;
        # high reload cost lifts a cold entry above an equally cold cheap one
        assert order[0] == "cold"
        assert order[-1] == "hot"

    def test_size_normalization_protects_hot_small_entries(self):
        clock = [10.0]
        pred = NextUsePredictor(clock=lambda: clock[0])
        t = 0.0
        while t < 10.0:
            pred.record("hot-small", now=t)
            t += 0.01
        pred.record("cold-big", now=0.0)
        pred.record("cold-big", now=9.0)
        hw = HardwareModel()
        pol = CostAware(pred, cost_fn=lambda e: hw.h2d_time(e.nbytes),
                        horizon_fn=lambda: 0.1)
        entries = [self._entry("hot-small", 1 * MB, 9.99),
                   self._entry("cold-big", 64 * MB, 9.0)]
        # absolute reload cost favors the big entry 64x, but per byte freed
        # the hot small entry is worth far more — the cold giant goes first
        assert [e.key for e in pol.order(entries)] == ["cold-big", "hot-small"]

    def test_make_policy_slo_constructs_fresh_costaware(self):
        a, b = make_policy("slo"), make_policy("slo")
        assert isinstance(a, CostAware) and isinstance(b, CostAware)
        assert a is not b and a.predictor is not b.predictor
        assert make_policy("lru") is make_policy("lru")  # singletons shared

    def test_tier_cache_accepts_slo_policy(self):
        c = TierCache(Tier.DEVICE, 4 * MB, "slo")
        c.make_room(MB)
        c.insert("a", MB)
        c.insert("b", MB)
        evicted = c.make_room(3 * MB)
        assert {e.key for e in evicted} <= {"a", "b"}
        assert c.used + 3 * MB <= c.capacity


# ------------------------------------------------------ MRM integration
class TestMRMSloWiring:
    @pytest.fixture
    def disk(self, tmp_path):
        d = DiskStore(str(tmp_path / "d"))
        for i in range(5):
            d.put(ModelKey("jax", f"m{i}"), _tensors(seed=i))
        return d

    def test_slo_policy_retains_hot_key_under_pressure(self, disk):
        mrm = MRM(disk, device_capacity=int(2.5 * MB),
                  host_capacity=int(2.5 * MB), policy="slo")
        clock = [0.0]
        mrm.slo.predictor.clock = lambda: clock[0]
        trace = [0, 1, 2, 0, 3, 4, 0] * 5
        for i in trace:
            h = mrm.open(ModelKey("jax", f"m{i}"))
            mrm.close(h)
            clock[0] += 0.01
        assert mrm.resident(ModelKey("jax", "m0"), Tier.DEVICE)
        stats = mrm.stats()
        assert stats["device"]["policy"] == "slo"
        # the hot key was loaded from disk exactly once
        assert stats["disk_loads"] < len(trace)

    def test_eviction_reload_stalls_attributed(self, disk):
        mrm = MRM(disk, device_capacity=int(1.5 * MB),
                  host_capacity=int(1.5 * MB), policy="slo")
        clock = [0.0]
        mrm.slo.predictor.clock = lambda: clock[0]
        for i in [0, 1, 0, 1, 0, 1]:  # two models, device fits one
            h = mrm.open(ModelKey("jax", f"m{i}"))
            mrm.close(h)
            clock[0] += 0.01
        stats = mrm.stats()
        # every reload follows an eviction of the same key moments earlier
        # — but these are NOT mispredictions: the predictor expected each
        # key straight back (gap 0.02s << horizon); capacity forced them
        assert stats["evicted_reload_stalls"] > 0
        assert stats["slo_stall_s"] > 0.0
        assert stats["mispredicted_evictions"] == 0

    def test_mispredicted_eviction_counted_on_surprise_return(self, disk):
        mrm = MRM(disk, device_capacity=int(1.5 * MB),
                  host_capacity=int(1.5 * MB), policy="slo")
        clock = [0.0]
        mrm.slo.predictor.clock = lambda: clock[0]

        def open_at(t, i):
            clock[0] = t
            mrm.close(mrm.open(ModelKey("jax", f"m{i}")))

        for t in (0.0, 5.0, 10.0):
            open_at(t, 0)               # m0 learns a 5s gap
        open_at(10.01, 1)               # evicts m0, predicted ~5s away
        open_at(10.02, 0)               # ...back 10ms later: mispredicted
        assert mrm.stats()["mispredicted_evictions"] == 1

    def test_demotion_saved_reload_counted(self, disk):
        # bench_pipeline's rotation: device AND host each fit ~2 of 3
        # models, so the cold chain's host copy gets evicted while its
        # model is still device-resident, and the later device eviction
        # pays a real D2H demotion — whose host hit on re-open is the
        # saved reload. Under LRU on purpose: the slo policy avoids these
        # demotions entirely (it sheds device-duplicates from HOST first),
        # and the metric wiring is policy-independent.
        mrm = MRM(disk, device_capacity=int(2.2 * MB),
                  host_capacity=int(2.2 * MB), policy="lru")
        tier_hits = []
        for i in [0, 1, 2] * 3:
            h = mrm.open(ModelKey("jax", f"m{i}"))
            tier_hits.append(h.timings.tier_hit)
            mrm.close(h)
        stats = mrm.stats()
        assert stats["demotions"] >= 1
        assert "host" in tier_hits
        assert stats["demotion_saved_reloads"] >= 1
        # a demotion-saved reload never exceeds the host hits it explains
        assert stats["demotion_saved_reloads"] <= tier_hits.count("host")

    def test_prefetch_plus_open_records_one_arrival(self, disk):
        """Regression: a router-style prefetch immediately followed by the
        function's own open of the same key is ONE usage event — recording
        both would halve the key's EWMA gap and inflate its reuse
        probability (cold: the open coalesces onto the prefetch's load;
        warm: the prefetch is a pure hint and only the open records)."""
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB,
                  policy="slo")
        key = ModelKey("jax", "m0")
        for _ in range(3):  # cold first round, warm after
            mrm.prefetch(key).result()
            mrm.close(mrm.open(key))
        assert mrm.slo.predictor.arrivals(key) == 3

    def test_note_deadline_updates_horizon(self, disk):
        mrm = MRM(disk, policy="slo")
        before = mrm.slo.horizon_s
        for _ in range(50):
            mrm.note_deadline(2.0)
        assert mrm.slo.horizon_s > before
        assert mrm.slo.horizon_s == pytest.approx(2.0, rel=0.1)
        mrm.note_deadline(None)  # no-ops must not raise
        MRM(disk, policy="lru").note_deadline(1.0)


class TestLoadDemotionRace:
    def test_concurrent_open_evict_never_collides_on_host(self, tmp_path):
        """Regression: a device eviction's demotion could insert a key
        into HOST between a cold loader's host-miss check and its host
        reservation ("already resident in HOST"). The loader now adopts
        the interchangeable demoted copy instead of colliding."""
        import random
        from repro.core.cache import CapacityError

        disk = DiskStore(str(tmp_path / "d"))
        for i in range(8):
            disk.put(ModelKey("jax", f"m{i}"), _tensors(seed=i))
        mrm = MRM(disk, device_capacity=3 * MB, host_capacity=4 * MB,
                  policy="slo")
        errs = []

        def worker(seed):
            rng = random.Random(seed)
            for _ in range(40):
                key = ModelKey("jax", f"m{rng.randrange(8)}")
                try:
                    h = mrm.open(key)
                    np.asarray(h.weights["w0"])
                    mrm.close(h)
                except CapacityError:
                    pass  # all entries referenced by peers: legal
                except Exception as e:  # noqa: BLE001
                    errs.append(e)

        threads = [threading.Thread(target=worker, args=(s,))
                   for s in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        assert not any(t.is_alive() for t in threads), "workers deadlocked"
        assert not errs, errs[:3]
        assert mrm.device.used <= mrm.device.capacity
        assert mrm.host.used <= mrm.host.capacity


# ----------------------------------------------------- write-back worker
class TestWritebackShutdown:
    def _mrm(self, tmp_path, objectstore):
        disk = DiskStore(str(tmp_path / "d"))
        disk.put(ModelKey("jax", "m"), _tensors())
        return MRM(disk, device_capacity=8 * MB, host_capacity=8 * MB,
                   objectstore=objectstore, writeback_to_cloud=True)

    def test_shutdown_drains_and_stops_worker(self, tmp_path):
        from repro.core import ObjectStore
        obj = ObjectStore(str(tmp_path / "cloud"))
        mrm = self._mrm(tmp_path, obj)
        h = mrm.open(ModelKey("jax", "m"))
        mrm.close(h)
        mrm.host.remove(ModelKey("jax", "m"))  # demotion event -> enqueue
        mrm.shutdown()
        assert mrm.metrics["cloud_writebacks"] == 1
        assert obj.contains(ModelKey("jax", "m"))
        # worker is gone; further host removals must not enqueue
        assert mrm._wb_thread is None
        mrm.shutdown()  # idempotent

    def test_writeback_errors_are_counted(self, tmp_path):
        class BrokenStore:
            def contains(self, key):
                return False

            def put_file(self, key, path, codec=None):
                raise IOError("upload failed")

        mrm = self._mrm(tmp_path, BrokenStore())
        h = mrm.open(ModelKey("jax", "m"))
        mrm.close(h)
        mrm.host.remove(ModelKey("jax", "m"))
        mrm.flush_writebacks()
        assert mrm.metrics["cloud_writeback_errors"] == 1
        assert mrm.metrics["cloud_writebacks"] == 0
        mrm.shutdown()


# ------------------------------------------------------- FaaS/Router SLO
class TestFaaSConcurrencyAndDeadlines:
    def _platform(self, tmp_path, n_models=1):
        disk = DiskStore(str(tmp_path / "disk"))
        for i in range(n_models):
            disk.put(ModelKey("jax", f"m{i}"), _tensors(seed=i))
        mrm = MRM(disk, device_capacity=32 * MB, host_capacity=64 * MB)
        return FaaSPlatform(mrm)

    def test_concurrent_invoke_accounting_exact(self, tmp_path):
        platform = self._platform(tmp_path)
        platform.deploy("f", lambda ctx, p: p)
        n_threads, per_thread = 8, 50
        errs = []

        def worker():
            try:
                for _ in range(per_thread):
                    platform.invoke("f", 1, deadline_s=10.0)
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        acct = platform.containers["f"].acct
        total = n_threads * per_thread
        assert acct.invocations == total
        assert acct.latencies.count == total
        assert acct.slo_invocations == total
        assert acct.total_s == pytest.approx(acct.latencies.total_s)

    def test_concurrent_mixed_tenant_accounting_exact(self, tmp_path):
        """Many threads, mixed tenants and deadlines: per-container AND
        per-tenant SLO accounting must both stay exact (DESIGN.md §12 —
        the tenant ledger shares no lock with the container ledger)."""
        from repro.core import RequestContext
        platform = self._platform(tmp_path)
        platform.deploy("f", lambda ctx, p: p)
        profiles = [("alice", 10.0), ("bob", 5.0), ("carol", None)]
        n_threads, per_thread = 9, 40
        errs = []

        def worker(i):
            tenant, deadline = profiles[i % len(profiles)]
            ctx = RequestContext(tenant=tenant, deadline_s=deadline)
            try:
                for _ in range(per_thread):
                    platform.invoke("f", 1, ctx=ctx)
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errs
        total = n_threads * per_thread
        acct = platform.containers["f"].acct
        assert acct.invocations == total
        # only deadline-carrying requests are SLO-scored: carol's are not
        per_tenant = total // len(profiles)
        assert acct.slo_invocations == 2 * per_tenant
        for tenant, deadline in profiles:
            ta = platform.tenant_acct[tenant]
            assert ta.invocations == per_tenant
            assert ta.latencies.count == per_tenant
            assert ta.slo_invocations == \
                (per_tenant if deadline is not None else 0)
            assert ta.total_s == pytest.approx(ta.latencies.total_s)

    def test_router_dispatch_counts_survive_races(self, tmp_path):
        nodes = []
        for i in range(3):
            disk = DiskStore(str(tmp_path / f"disk{i}"))
            disk.put(ModelKey("jax", "m"), _tensors(seed=i))
            node = FaaSPlatform(MRM(disk, device_capacity=16 * MB),
                                name=f"node{i}")
            node.deploy("f", lambda ctx, p: p)
            nodes.append(node)
        router = Router(nodes, policy="round_robin")
        n_threads, per_thread = 8, 100

        def worker():
            for _ in range(per_thread):
                router.invoke("f")

        threads = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sum(router.dispatches.values()) == n_threads * per_thread

    def test_deadline_violation_accounting(self, tmp_path):
        platform = self._platform(tmp_path)
        platform.deploy("slow", lambda ctx, p: time.sleep(0.02))
        platform.invoke("slow", deadline_s=1e-4)   # blown
        platform.invoke("slow", deadline_s=10.0)   # met
        platform.invoke("slow")                    # no deadline: not scored
        acct = platform.containers["slow"].acct
        assert acct.invocations == 3
        assert acct.slo_invocations == 2
        assert acct.slo_violations == 1
        assert acct.slo_slack_s < 10.0

    def test_router_deadline_slack_tiebreak(self, tmp_path):
        key = ModelKey("jax", "m0")
        warm, cold = (self._platform(tmp_path / "a"),
                      self._platform(tmp_path / "b"))
        for i, p in enumerate((warm, cold)):
            p.name = f"node{i}"
            p.deploy("f", lambda ctx, pl: pl, prewarm=False)
        # warm the first node's HOST tier only: equal DEVICE warmth (0 vs 0
        # is not the case — host beats disk), so give both disk copies and
        # check the slack tie-break picks the host-warm node
        warm.mrm.open(key, tier="host")
        assert warm.estimated_ready_s([key]) < cold.estimated_ready_s([key])
        router = Router([cold, warm])  # listed cold-first on purpose
        assert router.route("f", [key], deadline_s=0.05) is warm

    def test_estimated_ready_s_orders_by_tier(self, tmp_path):
        p = self._platform(tmp_path, n_models=3)
        k0, k1 = ModelKey("jax", "m0"), ModelKey("jax", "m1")
        h = p.mrm.open(k0)                 # device-resident
        p.mrm.open(k1, tier="host")        # host-resident
        dev = p.estimated_ready_s([k0])
        host = p.estimated_ready_s([k1])
        disk = p.estimated_ready_s([ModelKey("jax", "m2")])
        assert dev == 0.0
        assert dev < host < disk
        p.mrm.close(h)


# ---------------------------------------------------------- LatencyStats
class TestLatencyStats:
    def test_streaming_summary_is_exact_and_bounded(self):
        s = LatencyStats(reservoir_size=64)
        xs = [float(i) for i in range(1000)]
        for x in xs:
            s.append(x)
        assert s.count == 1000
        assert s.total_s == pytest.approx(sum(xs))
        assert s.min_s == 0.0 and s.max_s == 999.0
        assert s.mean() == pytest.approx(sum(xs) / len(xs))
        assert len(s) == 64  # bounded regardless of stream length

    def test_early_indexing_preserved(self):
        s = LatencyStats()
        s.append(0.5)
        s.append(0.1)
        assert s[0] == 0.5 and s[1] == 0.1

    def test_quantile_on_uniform_stream(self):
        s = LatencyStats(reservoir_size=512, seed=3)
        for i in range(5000):
            s.append(i / 5000.0)
        assert s.quantile(0.5) == pytest.approx(0.5, abs=0.1)
        assert s.quantile(0.99) >= s.quantile(0.5)
