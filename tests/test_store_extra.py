"""Extra store/MRM coverage: cloud throttling, eager host release, LRU
touch ordering through the MRM, store key listing."""
import time

import numpy as np
import pytest

from repro.core import CloudStore, DiskStore, MRM, ModelKey, Tier

MB = 1 << 20


def _tensors(nbytes=1 * MB, n=2, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32) for i in range(n)}


def test_cloud_download_models_time_and_copies(tmp_path):
    cloud = CloudStore(str(tmp_path / "cloud"), bw=100e6, rtt=5e-3,
                       simulate_time=True)
    disk = DiskStore(str(tmp_path / "disk"))
    key = ModelKey("jax", "m")
    cloud.put(key, _tensors(2 * MB))
    t0 = time.perf_counter()
    modeled, nbytes = cloud.download(key, disk)
    wall = time.perf_counter() - t0
    assert disk.contains(key)
    assert modeled == pytest.approx(5e-3 + nbytes / 100e6, rel=1e-6)
    # throttle sleeps toward the modeled time (capped at 0.25s)
    assert wall >= min(modeled, 0.25) * 0.5
    # bytes identical after the hop
    out = disk.open(key).read_all(verify=True)
    np.testing.assert_array_equal(out["w0"], _tensors(2 * MB)["w0"])


def test_store_keys_listing(tmp_path):
    disk = DiskStore(str(tmp_path / "d"))
    disk.put(ModelKey("fw1", "a", "1"), _tensors())
    disk.put(ModelKey("fw1", "b", "2"), _tensors())
    disk.put(ModelKey("fw2", "c", "1"), _tensors())
    keys = set(disk.keys())
    assert keys == {("fw1", "a", "1"), ("fw1", "b", "2"), ("fw2", "c", "1")}


def test_eager_reclaim_host_tier(tmp_path):
    disk = DiskStore(str(tmp_path / "d"))
    key = ModelKey("jax", "m")
    disk.put(key, _tensors())
    mrm = MRM(disk, device_capacity=64 * MB, host_capacity=64 * MB,
              eager_reclaim=True)
    h = mrm.open(key, tier="host")
    assert mrm.resident(key, Tier.HOST)
    mrm.close(h)
    assert not mrm.resident(key, Tier.HOST)  # eager: dropped at zero refs


def test_mru_protected_under_pressure(tmp_path):
    """The most-recently-used model must survive an eviction pass."""
    disk = DiskStore(str(tmp_path / "d"))
    keys = []
    for i in range(4):
        k = ModelKey("jax", f"m{i}")
        disk.put(k, _tensors(2 * MB, seed=i))
        keys.append(k)
    mrm = MRM(disk, device_capacity=5 * MB, host_capacity=64 * MB)
    for k in keys[:2]:
        mrm.close(mrm.open(k))
    mrm.close(mrm.open(keys[0]))       # touch m0 -> MRU
    mrm.close(mrm.open(keys[2]))       # forces eviction of LRU (m1)
    assert mrm.resident(keys[0], Tier.DEVICE)
    assert not mrm.resident(keys[1], Tier.DEVICE)


def test_double_close_is_idempotent(tmp_path):
    disk = DiskStore(str(tmp_path / "d"))
    key = ModelKey("jax", "m")
    disk.put(key, _tensors())
    mrm = MRM(disk, device_capacity=64 * MB)
    h = mrm.open(key)
    mrm.close(h)
    mrm.close(h)  # no-op, no negative refcount
    assert mrm.refcount(key) == 0
