"""Layer-granular streaming staging (DESIGN.md §9).

Covers the layer planner (window coverage/disjointness, expert splitting),
the StreamAssembler (out-of-order scatter, components filter), the
ObjectStore layer-aligned splitter + in-order shard callbacks, the cost
model recurrence, and the MRM partial-open surface — including the race
regressions: eviction pressure mid-stream must not reap the pinned
placeholder, a gather source dying after layer-k readiness never rolls
readiness back, concurrent wait_prefix + result() callers both complete,
and a corrupt mid-stream shard re-sources from CLOUD without re-fetching
already-verified layers.
"""
import hashlib
import os
import threading

import numpy as np
import pytest

from repro.core import (Cluster, DiskStore, HardwareModel, MRM, ModelKey,
                        ObjectStore, Tier)
from repro.core.costmodel import streaming_ttfl_time
from repro.core.layerplan import (LayerWindow, StreamAssembler,
                                  build_layer_plan, plan_for_file)
from repro.core.store import ModelFile, write_model

MB = 1 << 20
SHARD = 256 << 10


def _layered_tensors(L=4, d=16, moe=False, seed=0):
    rng = np.random.default_rng(seed)
    f32 = lambda *s: rng.standard_normal(s).astype(np.float32)
    t = {
        "embed": f32(64, d),
        "final_norm/scale": f32(d),
        "layers/attn/wq": f32(L, d, d),
        "layers/attn/wo": f32(L, d, d),
        "layers/ffn/w1": f32(L, d, 4 * d),
        "layers/ffn/w2": f32(L, 4 * d, d),
    }
    if moe:
        t["layers/ffn/w_gate"] = f32(L, 8, d)          # router: stays base
        t["layers/ffn/w_up"] = f32(L, 8, d, 2 * d)     # expert banks: 4-D
        t["layers/ffn/w_down"] = f32(L, 8, 2 * d, d)
    return t


def _write(tmp_path, tensors, name="m.trims"):
    path = str(tmp_path / name)
    write_model(path, tensors, meta={"arch": "test"})
    return path


def _mrm(disk, dev=64 * MB, host=256 * MB, **kw):
    return MRM(disk, device_capacity=dev, host_capacity=host,
               hw=kw.pop("hw", HardwareModel()), pipelined_staging=False,
               **kw)


# ------------------------------------------------------------- layer planner
class TestLayerPlan:
    def test_plan_covers_file_exactly(self, tmp_path):
        path = _write(tmp_path, _layered_tensors(L=4))
        plan, _ = plan_for_file(path)
        size = os.path.getsize(path)
        ranges = sorted(r for w in plan for r in w.ranges)
        pos = 0
        for off, n in ranges:            # disjoint and gap-free
            assert off == pos
            pos += n
        assert pos == size
        assert plan[0].group == "stem" and plan[0].layer_index == -1
        assert [w.layer_index for w in plan[1:]] == [0, 1, 2, 3]

    def test_expert_windows_split_from_base(self, tmp_path):
        path = _write(tmp_path, _layered_tensors(L=3, moe=True))
        plan, _ = plan_for_file(path)
        experts = [w for w in plan if w.group == "expert"]
        assert len(experts) == 3
        for w in experts:                # router (3-D) stays in the base
            assert all(n.rsplit("/", 1)[-1] in ("w_up", "w_down")
                       for n in w.tensor_names)
        # expert window i directly follows its base window in plan order
        for w in experts:
            base = plan[w.index - 1]
            assert base.group == "layer" and base.layer_index == w.layer_index

    def test_irregular_depth_falls_back_to_stem(self):
        from repro.core.store import TensorMeta
        tensors = {
            "layers/a": TensorMeta("layers/a", "float32", (4, 8), 0, 128, 0),
            "layers/b": TensorMeta("layers/b", "float32", (3, 8), 128, 96, 0),
        }
        plan = build_layer_plan(tensors, payload_base=64, file_size=288)
        # disagreeing depths: the dissenting group is folded into the stem
        stems = [w for w in plan if w.group == "stem"]
        assert any("layers/b" in w.tensor_names for w in stems)


# --------------------------------------------------------- stream assembler
class TestStreamAssembler:
    def _feed_all(self, path, asm, order="shuffled", chunk=1000):
        size = os.path.getsize(path)
        with open(path, "rb") as f:
            blob = f.read()
        frags = [(o, blob[o:o + chunk]) for o in range(0, size, chunk)]
        if order == "shuffled":
            rng = np.random.default_rng(1)
            rng.shuffle(frags)
        elif order == "reversed":
            frags.reverse()
        for off, data in frags:
            asm.feed(off, data)

    def test_out_of_order_feeds_reproduce_tensors(self, tmp_path):
        tensors = _layered_tensors(L=4)
        path = _write(tmp_path, tensors)
        fired = []
        asm = StreamAssembler(on_window=lambda w: fired.append(w.index))
        self._feed_all(path, asm, order="reversed")
        assert sorted(fired) == [w.index for w in asm.plan]
        for name, ref in tensors.items():
            np.testing.assert_array_equal(asm.arrays[name], ref)

    def test_components_filter_skips_groups(self, tmp_path):
        tensors = _layered_tensors(L=3, moe=True)
        path = _write(tmp_path, tensors)
        asm = StreamAssembler(components=("stem", "layer"))
        self._feed_all(path, asm)
        assert "layers/ffn/w_up" not in asm.arrays       # experts skipped
        assert "layers/attn/wq" in asm.arrays
        # excluded windows are born complete; included ones all landed
        assert asm.complete_count() == len(asm.plan)
        np.testing.assert_array_equal(asm.arrays["layers/attn/wq"],
                                      tensors["layers/attn/wq"])

    def test_duplicate_feeds_are_harmless(self, tmp_path):
        tensors = _layered_tensors(L=2)
        path = _write(tmp_path, tensors)
        fired = []
        asm = StreamAssembler(on_window=lambda w: fired.append(w.index))
        self._feed_all(path, asm, order="linear")
        n = len(fired)
        self._feed_all(path, asm, order="linear")        # full re-delivery
        assert len(fired) == n                           # no double events
        np.testing.assert_array_equal(asm.arrays["embed"], tensors["embed"])


# ------------------------------------------------- object store layer shards
class TestLayerShardedStore:
    def test_layer_put_records_window_rows(self, tmp_path):
        store = ObjectStore(str(tmp_path / "obj"), shard_bytes=SHARD)
        key = ModelKey("jax", "m", "1")
        store.put(key, _layered_tensors(L=4), shard_plan="layers")
        st = store.stat(key)
        assert st["shard_plan"] == "layers"
        shards = st["shards"]
        assert all("ranges" in s and "window" in s for s in shards)
        assert [s["index"] for s in shards] == list(range(len(shards)))
        # window ordinals are monotone across the table (execution order)
        wins = [s["window"] for s in shards]
        assert wins == sorted(wins)
        covered = sum(s["nbytes"] for s in shards)
        assert covered == st["nbytes"]

    def test_layer_fetch_roundtrip_and_callback_order(self, tmp_path):
        store = ObjectStore(str(tmp_path / "obj"), shard_bytes=SHARD)
        disk = DiskStore(str(tmp_path / "disk"))
        key = ModelKey("jax", "m", "1")
        tensors = _layered_tensors(L=4)
        store.put(key, tensors, shard_plan="layers")
        seen = []
        store.fetch(key, disk, on_shard=lambda s, d: seen.append(s["window"]))
        assert seen == sorted(seen) and len(seen) > 1
        mf = disk.open(key)
        for name, ref in tensors.items():
            np.testing.assert_array_equal(mf.read_tensor(name), ref)
        with open(mf.path, "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == \
                store.stat(key)["digest"]

    def test_classic_put_unchanged(self, tmp_path):
        store = ObjectStore(str(tmp_path / "obj"), shard_bytes=SHARD)
        key = ModelKey("jax", "m", "1")
        store.put(key, _layered_tensors(L=2))
        st = store.stat(key)
        assert st.get("shard_plan") is None
        assert st["shard_bytes"] == SHARD


# ----------------------------------------------------------------- cost model
class TestStreamingCostModel:
    def test_recurrence_bounds(self):
        wire = [2.0, 1.0, 1.0]
        post = [0.5, 0.5, 0.5]
        ttfl, done = streaming_ttfl_time(wire, post, lat=0.1)
        assert ttfl == done[0] == pytest.approx(0.1 + 2.0 + 0.5)
        # streamed total never beats the wire and never loses to serial
        assert done[-1] >= 0.1 + sum(wire)
        assert done[-1] <= 0.1 + sum(wire) + sum(post)
        assert done == sorted(done)

    def test_single_window_equals_serial(self):
        _, done = streaming_ttfl_time([3.0], [1.0], lat=0.5)
        assert done[-1] == pytest.approx(0.5 + 3.0 + 1.0)

    def test_hw_streaming_load_time(self):
        hw = HardwareModel()
        _, done = hw.streaming_load_time([MB, MB], 1e9, [0.0, 0.0])
        assert done[-1] < 2 * (MB / 1e9 + MB / hw.ingest_bw + MB / hw.h2d_bw)


# ------------------------------------------------------- MRM partial opens
class TestOpenStream:
    def _store_with(self, tmp_path, tensors, name="m"):
        store = ObjectStore(str(tmp_path / f"obj-{name}"), shard_bytes=SHARD)
        key = ModelKey("jax", name, "1")
        store.put(key, tensors, shard_plan="layers")
        return store, key

    def test_windows_arrive_in_execution_order(self, tmp_path):
        tensors = _layered_tensors(L=4)
        store, key = self._store_with(tmp_path, tensors)
        mrm = _mrm(DiskStore(str(tmp_path / "disk")), objectstore=store)
        fut = mrm.open_stream(key)
        n = fut.wait_prefix(2)
        assert n >= 2
        h = fut.result()
        assert fut.windows_ready() == len(fut.plan)
        for name, ref in tensors.items():
            np.testing.assert_array_equal(fut.arrays[name], ref)
        assert mrm.stats()["stream_loads"] == 1
        mrm.close(h)

    def test_concurrent_wait_prefix_and_result(self, tmp_path):
        """A wait_prefix(k) caller and a full result() caller racing on one
        future both complete (the satellite-3 concurrency case)."""
        tensors = _layered_tensors(L=6)
        store, key = self._store_with(tmp_path, tensors)
        mrm = _mrm(DiskStore(str(tmp_path / "disk")), objectstore=store)
        fut = mrm.open_stream(key)
        got = {}

        def waiter():
            got["prefix"] = fut.wait_prefix(3)

        def resolver():
            got["handle"] = fut.result(timeout=30)

        ts = [threading.Thread(target=waiter), threading.Thread(target=resolver)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30)
        assert got["prefix"] >= 3
        assert got["handle"] is not None
        assert fut.wait_prefix(10 ** 6) == len(fut.plan)   # clamped, done
        mrm.close(got["handle"])

    def test_coalesced_stream_mirrors_windows(self, tmp_path):
        tensors = _layered_tensors(L=4)
        store, key = self._store_with(tmp_path, tensors)
        mrm = _mrm(DiskStore(str(tmp_path / "disk")), objectstore=store)
        f1 = mrm.open_stream(key)
        f2 = mrm.open_stream(key)
        h1, h2 = f1.result(), f2.result(timeout=30)
        if f2.coalesced:                   # raced onto f1's load
            assert f2.wait_prefix(1) >= 1
        assert mrm.stats()["coalesced_loads"] >= 1
        for h in (h1, h2):
            mrm.close(h)

    def test_private_components_load_bypasses_cache(self, tmp_path):
        tensors = _layered_tensors(L=3, moe=True)
        store, key = self._store_with(tmp_path, tensors)
        mrm = _mrm(DiskStore(str(tmp_path / "disk")), objectstore=store)
        fut = mrm.open_stream(key, components=("stem", "layer"))
        h = fut.result()
        assert h.private
        assert "layers/ffn/w_up" not in h.weights
        assert not mrm.resident(key, Tier.HOST)      # never cached
        assert mrm.stats()["partial_loads"] == 1
        mrm.close(h)                                  # must not underflow
        full = mrm.open(key, tier="host")             # full load still clean
        np.testing.assert_array_equal(
            np.asarray(full.weights["layers/ffn/w_up"]),
            tensors["layers/ffn/w_up"])
        mrm.close(full)

    def test_eviction_pressure_mid_stream_spares_placeholder(self, tmp_path):
        """Host-tier pressure while a stream is in flight: the pinned
        placeholder reservation survives make_room; victims come from the
        unpinned population and the stream completes intact."""
        big = _layered_tensors(L=8, d=64, seed=1)
        store, key = self._store_with(tmp_path, big, name="big")
        disk = DiskStore(str(tmp_path / "disk"))
        big_nb = sum(a.nbytes for a in big.values())
        small = {f"s{i}": np.zeros(big_nb // 16, np.float32)
                 for i in range(4)}
        small_nb = sum(a.nbytes for a in small.values())
        mrm = _mrm(disk, host=big_nb + 3 * small_nb, objectstore=store)
        skeys = []
        for i in range(4):
            sk = ModelKey("jax", f"small{i}", "1")
            disk.put(sk, small)
            skeys.append(sk)
        for sk in skeys[:2]:             # resident, unpinned, evictable
            mrm.close(mrm.open(sk, tier="host"))

        paused, resume = threading.Event(), threading.Event()
        real_fetch = store.fetch

        def pausing_fetch(k, dst, report_out=None, on_shard=None):
            def cb(row, data):
                if on_shard is not None:
                    on_shard(row, data)
                if not paused.is_set():
                    paused.set()
                    assert resume.wait(30)
            return real_fetch(k, dst, report_out=report_out, on_shard=cb)

        store.fetch = pausing_fetch
        try:
            fut = mrm.open_stream(key)
            assert paused.wait(30)
            # mid-stream: thrash the host tier
            for sk in skeys[2:]:
                mrm.close(mrm.open(sk, tier="host"))
            with mrm.host.lock:
                e = mrm.host.peek(key)
                assert e is not None and e.pinned    # placeholder survived
            resume.set()
            h = fut.result(timeout=60)
        finally:
            store.fetch = real_fetch
            resume.set()
        for name, ref in big.items():
            np.testing.assert_array_equal(fut.arrays[name], ref)
        assert mrm.resident(key, Tier.HOST)
        mrm.close(h)


# ------------------------------------------------------ cluster + streaming
def _layered_cluster(tmp_path, n=3, L=6):
    tensors = _layered_tensors(L=L, d=64, seed=2)
    store = ObjectStore(str(tmp_path / "cloud"), shard_bytes=SHARD)
    key = ModelKey("jax", "big", "1")
    store.put(key, tensors, shard_plan="layers")
    cluster = Cluster(objectstore=store)
    for i in range(n):
        cluster.add_node(f"node{i}",
                         _mrm(DiskStore(str(tmp_path / f"disk{i}"))))
    return cluster, store, key, tensors


class TestStreamingGather:
    def test_gather_feeds_windows(self, tmp_path):
        cluster, store, key, tensors = _layered_cluster(tmp_path)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        fut = n0.mrm.open_stream(key)
        h = fut.result(timeout=60)
        assert fut.timings.tier_hit == "gather"
        assert fut.windows_ready() == len(fut.plan)
        for name, ref in tensors.items():
            np.testing.assert_array_equal(fut.arrays[name], ref)
        n0.mrm.close(h)

    def test_source_death_after_layer_k_keeps_readiness(self, tmp_path,
                                                        monkeypatch):
        """A gather source dropped after early windows fired: the re-plan
        re-sources the remaining shards, readiness never rolls back, and
        the stream still completes every window."""
        cluster, store, key, tensors = _layered_cluster(tmp_path)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0 = cluster.node("node0")
        state = {"fetched": 0, "prefix_at_death": None, "fut": None}
        real = n0._fetch_one_shard

        def dying_fetch(k, st, row, plan_gen, loads):
            data = real(k, st, row, plan_gen, loads)
            state["fetched"] += 1
            if state["fetched"] == 2:
                f = state["fut"]
                state["prefix_at_death"] = f.windows_ready() if f else 0
                cluster.directory.drop_node("node2")
            return data

        monkeypatch.setattr(n0, "_fetch_one_shard", dying_fetch)
        fut = n0.mrm.open_stream(key)
        state["fut"] = fut
        h = fut.result(timeout=60)
        assert state["prefix_at_death"] is not None
        assert fut.windows_ready() == len(fut.plan)
        assert fut.windows_ready() >= state["prefix_at_death"]
        assert n0.stats()["plan_replans"] >= 1
        for name, ref in tensors.items():
            np.testing.assert_array_equal(fut.arrays[name], ref)
        with open(n0.mrm.disk.path_for(key), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == \
                store.stat(key)["digest"]
        n0.mrm.close(h)

    def test_corrupt_shard_falls_back_without_refetching_verified(
            self, tmp_path):
        """A corrupt peer mid-stream: its shards re-source from CLOUD
        individually — shards already verified from the healthy peer are
        NOT re-downloaded (cloud shard count stays below the table size)."""
        cluster, store, key, tensors = _layered_cluster(tmp_path)
        cluster.scatter(key, node_names=["node1", "node2"])
        n0, n1 = cluster.node("node0"), cluster.node("node1")
        # size-preserving corruption of ONE of node1's shard blobs
        bad = n1.local_shards(key)[0]
        with open(n1._shard_path(key, bad), "r+b") as f:
            f.write(b"\xff" * 64)
        fut = n0.mrm.open_stream(key)
        h = fut.result(timeout=60)
        stats = n0.stats()
        n_shards = len(store.stat(key)["shards"])
        assert stats["gather_fallbacks"] > 0
        assert 0 < stats["shards_from_cloud"] < n_shards
        assert fut.windows_ready() == len(fut.plan)
        for name, ref in tensors.items():
            np.testing.assert_array_equal(fut.arrays[name], ref)
        with open(n0.mrm.disk.path_for(key), "rb") as f:
            assert hashlib.sha256(f.read()).hexdigest() == \
                store.stat(key)["digest"]
        n0.mrm.close(h)


# ------------------------------------------------------------ serving engine
class TestStreamingEngine:
    def test_streamed_generate_matches_batch(self, tmp_path):
        import jax
        from repro.configs import get_config
        from repro.models.model import init_params
        from repro.serving.engine import InferenceEngine, publish_model

        cfg = get_config("olmo-1b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        d_ref = DiskStore(str(tmp_path / "ref"))
        key = publish_model(d_ref, cfg, params, name="olmo-1b")
        eng_ref = InferenceEngine(d_ref, _mrm(d_ref))

        store = ObjectStore(str(tmp_path / "obj"))
        store.put_file(key, d_ref.path_for(key), shard_plan="layers",
                       shard_bytes=SHARD)
        d_cold = DiskStore(str(tmp_path / "cold"))
        eng = InferenceEngine(d_cold, _mrm(d_cold, objectstore=store),
                              streaming=True)
        toks = (np.arange(6, dtype=np.int32).reshape(1, 6)) % cfg.vocab_size
        out_ref, _ = eng_ref.generate("olmo-1b", toks, max_new_tokens=3)
        out_s, st = eng.generate("olmo-1b", toks, max_new_tokens=3)
        assert st.streamed and st.ttft_s > 0
        np.testing.assert_array_equal(out_ref, out_s)
        # warm re-serve falls back to the batch path, same tokens
        out_w, st_w = eng.generate("olmo-1b", toks, max_new_tokens=3)
        assert not st_w.streamed
        np.testing.assert_array_equal(out_ref, out_w)
        # satellite: first-execution compile time folded into compile_s
        assert st.compile_s > 0
