"""Substrate: checkpoint roundtrip + elastic restore, async manager,
fault-tolerant training loop, straggler watchdog, gradient compression,
data pipeline determinism."""
import os
import time

import numpy as np
import pytest

pytestmark = pytest.mark.slow  # skipped by scripts/ci.sh --fast

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.configs import get_config
from repro.data.pipeline import Prefetcher, make_batch
from repro.launch.train import Trainer, TrainerConfig
from repro.runtime import (FailureInjector, SimulatedFailure, Watchdog,
                           quantized_allreduce)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.bfloat16),
                   "layers": {"ln": jnp.ones((4,), jnp.float32)}},
        "opt_mu": {"w": jnp.zeros((8, 16), jnp.float32),
                   "layers": {"ln": jnp.zeros((4,), jnp.float32)}},
        "opt_step": jnp.int32(7),
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        st = _state()
        save_checkpoint(str(tmp_path), 7, st)
        assert latest_step(str(tmp_path)) == 7
        step, back = restore_checkpoint(str(tmp_path))
        assert step == 7
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_elastic_restore_to_sharded(self, tmp_path):
        """Save unsharded, restore onto a mesh (mesh-shape change across
        restarts — elastic scaling)."""
        st = _state()
        save_checkpoint(str(tmp_path), 1, st)
        mesh = jax.make_mesh((1,), ("data",))
        sh = jax.tree.map(
            lambda x: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), st)
        _, back = restore_checkpoint(str(tmp_path), shardings=sh)
        assert all(hasattr(l, "sharding") for l in jax.tree.leaves(back))

    def test_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), every=1, keep=2,
                                async_mode=False)
        for s in range(1, 5):
            mgr.save(s, _state(s))
        dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert dirs == ["step_00000003", "step_00000004"]
        assert latest_step(str(tmp_path)) == 4

    def test_async_manager(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), every=2, keep=5)
        for s in range(1, 7):
            mgr.save(s, _state(s))
        mgr.wait()
        assert sorted(mgr.saved_steps) == [2, 4, 6]


class TestFaultTolerance:
    def test_training_survives_injected_failures(self, tmp_path):
        cfg = get_config("olmo-1b").reduced().replace(n_layers=2)
        tc = TrainerConfig(batch_size=2, seq_len=32, steps=12,
                           ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100)
        inj = FailureInjector(fail_at_steps=[5, 9])
        tr = Trainer(cfg, tc, injector=inj)
        out = tr.run_with_restarts(max_restarts=4)
        assert tr.restarts == 2
        steps_seen = [h["step"] for h in tr.history]
        assert max(steps_seen) == 11          # completed all 12 steps
        # losses decrease overall
        assert out["history"][-1]["loss"] < out["history"][0]["loss"] + 0.5

    def test_restart_resumes_from_checkpoint_not_scratch(self, tmp_path):
        cfg = get_config("olmo-1b").reduced().replace(n_layers=2)
        tc = TrainerConfig(batch_size=2, seq_len=32, steps=8,
                           ckpt_dir=str(tmp_path), ckpt_every=4, log_every=100)
        inj = FailureInjector(fail_at_steps=[6])
        tr = Trainer(cfg, tc, injector=inj)
        tr.run_with_restarts()
        steps = [h["step"] for h in tr.history]
        # after failing at 6, resume happens from ckpt@4 (not step 0)
        resumed = steps[steps.index(6) + 1:] if 6 in steps else steps
        assert 0 not in resumed

    def test_watchdog_detects_stall(self):
        wd = Watchdog(timeout=0.15, poll=0.02)
        wd.beat()
        time.sleep(0.4)
        wd.stop()
        assert len(wd.stalls) >= 1

    def test_watchdog_quiet_when_beating(self):
        wd = Watchdog(timeout=0.3, poll=0.02)
        for _ in range(10):
            wd.beat()
            time.sleep(0.03)
        wd.stop()
        assert wd.stalls == []


class TestCompression:
    def test_quantized_allreduce_accuracy(self):
        mesh = jax.make_mesh((1,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)

        from repro.jax_compat import shard_map
        out = shard_map(
            lambda v: quantized_allreduce(v, "pod"), mesh=mesh,
            in_specs=jax.sharding.PartitionSpec(),
            out_specs=jax.sharding.PartitionSpec(),
            check=False, axis_names={"pod"})(x)
        err = np.abs(np.asarray(out) - np.asarray(x)).max()
        scale = float(jnp.abs(x).max()) / 127
        assert err <= scale * 0.51 + 1e-7   # quantization bound

    def test_quantized_wire_is_int8(self):
        mesh = jax.make_mesh((1,), ("pod",))
        from repro.jax_compat import shard_map
        f = shard_map(lambda v: quantized_allreduce(v, "pod"), mesh=mesh,
                      in_specs=jax.sharding.PartitionSpec(),
                      out_specs=jax.sharding.PartitionSpec(),
                      check=False, axis_names={"pod"})
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((128, 128), jnp.float32)).as_text()
        assert "all_gather" in txt or "all-gather" in txt
        assert "tensor<1x128x128xi8>" in txt or "s8[" in txt or "i8" in txt


class TestData:
    def test_deterministic_and_distinct(self):
        cfg = get_config("olmo-1b").reduced()
        b1 = make_batch(cfg, 3, 4, 16)
        b2 = make_batch(cfg, 3, 4, 16)
        b3 = make_batch(cfg, 4, 4, 16)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(b1["tokens"], b3["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:],
                                      np.asarray(b1["labels"])[:, :-1])
        assert int(jnp.max(b1["tokens"])) < cfg.vocab_size

    def test_prefetcher(self):
        cfg = get_config("olmo-1b").reduced()
        pf = Prefetcher(cfg, 2, 16, depth=2, start_step=5)
        s0, b0 = next(pf)
        s1, b1 = next(pf)
        pf.stop()
        assert (s0, s1) == (5, 6)
        ref = make_batch(cfg, 5, 2, 16)
        np.testing.assert_array_equal(b0["tokens"], ref["tokens"])
