"""End-to-end behaviour of the full system: train -> publish -> FaaS-serve
through TrIMS, with isolation and sharing verified along the way."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # skipped by scripts/ci.sh --fast

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import DiskStore, FaaSPlatform, MRM
from repro.launch.train import Trainer, TrainerConfig
from repro.runtime import FailureInjector
from repro.serving import InferenceEngine, publish_model


@pytest.fixture(scope="module")
def trained(tmp_path_factory):
    tmp = tmp_path_factory.mktemp("system")
    cfg = get_config("olmo-1b").reduced().replace(n_layers=2, d_model=64)
    tc = TrainerConfig(batch_size=2, seq_len=32, steps=16, warmup=2,
                       peak_lr=1e-3, ckpt_dir=str(tmp / "ckpt"),
                       ckpt_every=4, log_every=100)
    tr = Trainer(cfg, tc, injector=FailureInjector(fail_at_steps=[5]))
    out = tr.run_with_restarts(max_restarts=2)
    disk = DiskStore(str(tmp / "models"))
    publish_model(disk, cfg, out["params"], name="sysmodel")
    return cfg, disk, out


def test_training_converged_through_failure(trained):
    _, _, out = trained
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_trained_model_served_through_trims(trained):
    cfg, disk, out = trained
    mrm = MRM(disk, device_capacity=2 << 30)
    engine = InferenceEngine(disk, mrm)
    toks = np.arange(1, 17, dtype=np.int32)[None, :]
    gen1, st1 = engine.generate("sysmodel", toks, max_new_tokens=4)
    gen2, st2 = engine.generate("sysmodel", toks, max_new_tokens=4)
    np.testing.assert_array_equal(gen1, gen2)       # deterministic
    assert st2.tier_hit == "device"                  # warm second hit
    assert mrm.stats()["disk_loads"] == 1


def test_faas_pipeline_over_trained_model(trained):
    cfg, disk, _ = trained
    mrm = MRM(disk, device_capacity=2 << 30)
    platform = FaaSPlatform(mrm)

    def summarize(ctx, tokens):
        m = ctx.load_model("repro-jax", "sysmodel")
        # tenant computes over shared weights without owning them
        return float(np.asarray(m.weights["embed"], np.float32).mean())

    platform.deploy("tenant_a", summarize)
    platform.deploy("tenant_b", summarize)
    ra = platform.invoke("tenant_a", None)
    rb = platform.invoke("tenant_b", None)
    assert ra == rb
    assert mrm.stats()["disk_loads"] == 1            # shared, loaded once
