"""Multi-tenant isolation (core.tenant + the RequestContext plumbing).

Covers the DESIGN.md §12 stack: context validation at the single deadline
boundary, wire round-trips, per-tenant residency accounting via cache
listeners, fair shares and eviction weights, admission verdicts, the
MRM's quota/deadline staging degrades, the FaaS invoke path (per-tenant
SLO accounting, AdmissionError), and the context crossing the shm_ipc
process boundary.
"""
import math
import threading

import numpy as np
import pytest

from repro.core import (AdmissionError, DiskStore, FaaSPlatform, MRM,
                        ModelKey, RequestContext, TenantQuota,
                        TenantRegistry)
from repro.core.tenant import DEFAULT_TENANT

MB = 1 << 20


def _tensors(nbytes=1 * MB, n=2, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32)
            for i in range(n)}


# ------------------------------------------------------- RequestContext
class TestRequestContext:
    def test_defaults_are_anonymous_critical(self):
        ctx = RequestContext()
        assert ctx.tenant == DEFAULT_TENANT
        assert ctx.slo_class == "critical"
        assert ctx.deadline_s is None
        assert ctx.priority == 0

    def test_deadline_validated_once_at_the_boundary(self):
        assert RequestContext(deadline_s=0.5).deadline_s == 0.5
        assert RequestContext(deadline_s=1).deadline_s == 1.0  # int -> float
        for bad in (0, -1.0, float("inf"), float("nan")):
            with pytest.raises(ValueError):
                RequestContext(deadline_s=bad)

    def test_tenant_and_class_validated(self):
        with pytest.raises(ValueError):
            RequestContext(tenant="")
        with pytest.raises(ValueError):
            RequestContext(slo_class="interactive")

    def test_frozen(self):
        ctx = RequestContext()
        with pytest.raises(Exception):
            ctx.tenant = "other"

    def test_coerce_bridges_legacy_deadline(self):
        assert RequestContext.coerce() is None
        assert RequestContext.coerce(None, None) is None
        wrapped = RequestContext.coerce(deadline_s=2.0)
        assert wrapped.tenant == DEFAULT_TENANT
        assert wrapped.deadline_s == 2.0
        explicit = RequestContext(tenant="a", deadline_s=9.0)
        # an explicit context wins over a stray legacy deadline
        assert RequestContext.coerce(explicit, 1.0) is explicit
        with pytest.raises(TypeError):
            RequestContext.coerce({"tenant": "a"})
        with pytest.raises(ValueError):
            RequestContext.coerce(deadline_s=-3)

    def test_wire_roundtrip(self):
        ctx = RequestContext(tenant="t1", slo_class="batch",
                             deadline_s=0.25, priority=3)
        assert RequestContext.from_wire(ctx.to_wire()) == ctx
        assert RequestContext.from_wire(None) is None
        # no-deadline contexts omit the key entirely (msgpack-lean)
        assert "deadline_s" not in RequestContext(tenant="t").to_wire()
        # unknown keys from a newer peer are ignored
        got = RequestContext.from_wire({"tenant": "t2", "shiny": True})
        assert got.tenant == "t2" and got.deadline_s is None

    def test_admission_error_carries_verdict(self):
        ctx = RequestContext(tenant="t", slo_class="batch")
        err = AdmissionError("shed", ctx, "tiers under pressure")
        assert err.action == "shed"
        assert err.ctx is ctx
        assert "t" in str(err)


# ------------------------------------------------------- TenantRegistry
class TestTenantRegistry:
    @pytest.fixture
    def disk(self, tmp_path):
        d = DiskStore(str(tmp_path / "d"))
        for i in range(8):
            d.put(ModelKey("jax", f"m{i}"), _tensors(seed=i))
        return d

    def test_attribution_and_residency_accounting(self, disk):
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB)
        reg = TenantRegistry().attach(mrm)
        assert mrm.tenants is reg
        a = RequestContext(tenant="alice")
        h = mrm.open(ModelKey("jax", "m0"), ctx=a)
        assert reg.tenant_of(ModelKey("jax", "m0")) == "alice"
        assert reg.usage_bytes("alice", "device") == h.nbytes
        assert reg.usage_bytes("alice", "host") == h.nbytes  # cold chain
        mrm.close(h)
        # eviction releases the bytes back
        mrm.device.remove(ModelKey("jax", "m0"))
        assert reg.usage_bytes("alice", "device") == 0
        mrm.shutdown()

    def test_unattributed_bytes_charge_the_default_tenant(self, disk):
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB)
        reg = TenantRegistry().attach(mrm)
        h = mrm.open(ModelKey("jax", "m1"))  # no ctx: legacy caller
        assert reg.usage_bytes(DEFAULT_TENANT, "device") == h.nbytes
        mrm.close(h)
        mrm.shutdown()

    def test_attach_backfills_resident_entries(self, disk):
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB)
        h = mrm.open(ModelKey("jax", "m2"))  # resident before attach
        reg = TenantRegistry().attach(mrm)
        assert reg.usage_bytes(DEFAULT_TENANT, "device") == h.nbytes
        mrm.close(h)
        mrm.shutdown()

    def test_fair_bytes_quota_and_share_split(self):
        reg = TenantRegistry()
        reg._capacity["device"] = 100
        reg.set_quota("capped", TenantQuota(device_bytes=10))
        reg.set_quota("big", TenantQuota(share=3.0))
        reg.set_quota("small", TenantQuota(share=1.0))
        assert reg.fair_bytes("capped", "device") == 10.0
        # share split runs over every known tenant (3 + 1 + capped's 1)
        assert reg.fair_bytes("big", "device") == pytest.approx(60.0)
        assert reg.fair_bytes("small", "device") == pytest.approx(20.0)

    def test_overage_and_eviction_weight(self):
        reg = TenantRegistry()
        reg._capacity["device"] = 100
        reg.set_quota("t", TenantQuota(device_bytes=50))
        reg.note_open("k", "t")
        reg._usage[("device", "t")] = 100  # 2x its share
        assert reg.overage("t", "device") == pytest.approx(1.0)
        assert reg.eviction_weight("k", "device") == pytest.approx(
            1.0 + reg.overage_weight_k)
        # an in-share tenant's bytes keep weight 1 (never penalized)
        reg.note_open("k2", "other")
        assert reg.eviction_weight("k2", "device") == 1.0

    def test_would_exceed(self):
        reg = TenantRegistry()
        reg.set_quota("t", TenantQuota(device_bytes=100))
        reg._usage[("device", "t")] = 60
        assert not reg.would_exceed("t", "device", 40)
        assert reg.would_exceed("t", "device", 41)
        assert not reg.would_exceed("uncapped", "device", 1 << 40)

    def test_admission_verdicts(self):
        reg = TenantRegistry()
        reg._capacity["device"] = 100
        crit = RequestContext(tenant="a", slo_class="critical")
        batch = RequestContext(tenant="b", slo_class="batch")
        # critical admits even at full pressure; None = legacy traffic
        assert reg.admit(crit, 1.0, 1.0) == "admit"
        assert reg.admit(None, 1.0, 1.0) == "admit"
        # batch admits while either tier has headroom
        assert reg.admit(batch, 1.0, 0.5) == "admit"
        assert reg.admit(batch, 0.5, 1.0) == "admit"
        # both tiers pressured: queue while in-share...
        assert reg.admit(batch, 1.0, 1.0) == "queue"
        # ...shed once the tenant is over its fair share
        reg.set_quota("b", TenantQuota(device_bytes=10))
        reg._usage[("device", "b")] = 30
        assert reg.admit(batch, 1.0, 1.0) == "shed"
        st = reg.stats()
        assert st["a"]["admitted"] == 1
        assert st["b"]["admitted"] == 2
        assert st["b"]["queued"] == 1
        assert st["b"]["shed"] == 1

    def test_attribution_map_is_bounded(self, monkeypatch):
        import repro.core.tenant as tenant_mod
        monkeypatch.setattr(tenant_mod, "_KEY_TENANT_CAP", 4)
        reg = TenantRegistry()
        for i in range(8):
            reg.note_open(f"k{i}", "t")
        assert len(reg._key_tenant) <= 5
        assert reg.tenant_of("k0") == DEFAULT_TENANT  # pruned -> default
        assert reg.tenant_of("k7") == "t"


# --------------------------------------------------- MRM staging degrades
class TestMRMAdmission:
    @pytest.fixture
    def disk(self, tmp_path):
        d = DiskStore(str(tmp_path / "d"))
        for i in range(4):
            d.put(ModelKey("jax", f"m{i}"), _tensors(seed=i))
        return d

    def test_quota_exhaustion_degrades_to_host(self, disk):
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB)
        reg = TenantRegistry().attach(mrm)
        ctx = RequestContext(tenant="t")
        h0 = mrm.open(ModelKey("jax", "m0"), ctx=ctx)
        reg.set_quota("t", TenantQuota(device_bytes=h0.nbytes))
        h1 = mrm.open(ModelKey("jax", "m1"), ctx=ctx)  # would break quota
        assert mrm.device.peek(ModelKey("jax", "m1")) is None
        assert mrm.host.peek(ModelKey("jax", "m1")) is not None
        assert mrm.metrics["quota_degraded"] == 1
        assert reg.stats()["t"]["degraded"] == 1
        mrm.close(h0)
        mrm.close(h1)
        mrm.shutdown()

    def test_blown_deadline_skips_device_staging(self, disk):
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB)
        TenantRegistry().attach(mrm)
        # a cold load can never be device-ready in 1ns: don't burn H2D on it
        ctx = RequestContext(tenant="t", deadline_s=1e-9)
        h = mrm.open(ModelKey("jax", "m2"), ctx=ctx)
        assert mrm.device.peek(ModelKey("jax", "m2")) is None
        assert mrm.metrics["admission_degraded"] == 1
        mrm.close(h)
        mrm.shutdown()

    def test_without_registry_context_is_inert(self, disk):
        mrm = MRM(disk, device_capacity=16 * MB, host_capacity=32 * MB)
        ctx = RequestContext(tenant="t", deadline_s=1e-9)
        h = mrm.open(ModelKey("jax", "m3"), ctx=ctx)  # no degrade, no error
        assert mrm.device.peek(ModelKey("jax", "m3")) is not None
        assert mrm.metrics["admission_degraded"] == 0
        mrm.close(h)
        mrm.shutdown()

    def test_note_deadline_rejects_invalid_via_boundary(self, disk):
        mrm = MRM(disk, policy="slo")
        with pytest.raises(ValueError):
            mrm.note_deadline(-1.0)
        mrm.note_deadline(None)  # still a no-op
        mrm.shutdown()


# ------------------------------------------------- FaaS invoke + tenancy
class TestFaaSTenancy:
    def _platform(self, tmp_path, tenants=None, n_models=2):
        disk = DiskStore(str(tmp_path / "disk"))
        for i in range(n_models):
            disk.put(ModelKey("jax", f"m{i}"), _tensors(seed=i))
        mrm = MRM(disk, device_capacity=32 * MB, host_capacity=64 * MB)
        return FaaSPlatform(mrm, tenants=tenants)

    def test_context_visible_to_function_and_attributes_loads(self, tmp_path):
        reg = TenantRegistry()
        platform = self._platform(tmp_path, tenants=reg)
        assert platform.mrm.tenants is reg  # auto-attached
        seen = {}

        def fn(c, p):
            seen["ctx"] = c.current_ctx
            m = c.load_model("jax", "m0")  # inherits the invoke's context
            c.unload_model(m)
            return p

        platform.deploy("f", fn, prewarm=False)
        ctx = RequestContext(tenant="alice", deadline_s=5.0)
        assert platform.invoke("f", 42, ctx=ctx) == 42
        assert seen["ctx"] is ctx
        assert platform.containers["f"].current_ctx is None  # restored
        assert reg.tenant_of(ModelKey("jax", "m0")) == "alice"
        assert reg.usage_bytes("alice", "device") > 0
        acct = platform.tenant_acct["alice"]
        assert acct.invocations == 1 and acct.slo_invocations == 1

    def test_admission_error_raised_before_the_function_runs(self, tmp_path):
        reg = TenantRegistry()
        platform = self._platform(tmp_path, tenants=reg)
        ran = []
        platform.deploy("f", lambda c, p: ran.append(p), prewarm=False)
        platform._tier_frac = lambda cache: 1.0  # both tiers saturated
        batch = RequestContext(tenant="b", slo_class="batch")
        with pytest.raises(AdmissionError) as ei:
            platform.invoke("f", 1, ctx=batch)
        assert ei.value.action == "queue"
        reg.set_quota("b", TenantQuota(device_bytes=1))
        reg._usage[("device", "b")] = 2
        with pytest.raises(AdmissionError) as ei:
            platform.invoke("f", 1, ctx=batch)
        assert ei.value.action == "shed"
        assert not ran  # refused work never executed
        # critical work still admits at full pressure
        crit = RequestContext(tenant="a", slo_class="critical")
        platform.invoke("f", 2, ctx=crit)
        assert ran == [2]

    def test_legacy_deadline_keyword_still_works(self, tmp_path):
        platform = self._platform(tmp_path)
        platform.deploy("f", lambda c, p: p, prewarm=False)
        assert platform.invoke("f", 1, deadline_s=10.0) == 1
        acct = platform.tenant_acct[DEFAULT_TENANT]
        assert acct.slo_invocations == 1
        with pytest.raises(ValueError):
            platform.invoke("f", 1, deadline_s=0.0)  # boundary validation


# -------------------------------------------- context across the process
class TestContextOverShmIpc:
    def test_wire_context_attributes_the_daemon_side_open(self, tmp_path):
        from repro.core.shm_ipc import MRMServer, RemoteTrimsClient
        disk = DiskStore(str(tmp_path / "disk"))
        disk.put(ModelKey("jax", "shared"), _tensors(seed=7))
        mrm = MRM(disk, device_capacity=64 * MB, host_capacity=256 * MB,
                  use_shm=True)
        reg = TenantRegistry().attach(mrm)
        srv = MRMServer(mrm, str(tmp_path / "mrm.sock"))
        try:
            client = RemoteTrimsClient(srv.sock_path)
            ctx = RequestContext(tenant="remote-tenant", deadline_s=30.0)
            h = client.open("jax", "shared", ctx=ctx)
            assert reg.tenant_of(ModelKey("jax", "shared")) == "remote-tenant"
            assert reg.usage_bytes("remote-tenant", "host") > 0
            client.close(h)
            # a context-free client (an old binary) still works unchanged
            h2 = client.open("jax", "shared")
            client.close(h2)
            client.disconnect()
        finally:
            srv.stop()
            for e in list(mrm.host.entries.values()):
                if e.payload is not None:
                    e.payload.release()
