"""Async tier-hierarchy: LoadFuture opens, chunked pipelined staging,
eviction-as-demotion, prefetch/pinning, and the pipelined cost model."""
import threading

import numpy as np
import pytest

from repro.core import (CapacityError, DiskStore, HardwareModel, MRM,
                        ModelKey, Tier)
from repro.core.pipeline import plan_chunks, run_pipeline

MB = 1 << 20


def _tensors(nbytes=1 * MB, n=4, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32) for i in range(n)}


@pytest.fixture
def disk(tmp_path):
    return DiskStore(str(tmp_path / "disk"))


def _mrm(disk, dev=8 * MB, host=32 * MB, **kw):
    return MRM(disk, device_capacity=dev, host_capacity=host, **kw)


# ------------------------------------------------------------- pipeline unit
class TestPipeline:
    def test_plan_chunks_groups_and_preserves_order(self):
        items = [(f"t{i}", 3) for i in range(7)]
        chunks = plan_chunks(items, 6)
        assert chunks == [["t0", "t1"], ["t2", "t3"], ["t4", "t5"], ["t6"]]
        # oversized item gets its own chunk
        assert plan_chunks([("a", 100), ("b", 1)], 10) == [["a"], ["b"]]

    def test_run_pipeline_outputs_and_stats(self):
        outs, report = run_pipeline(
            list(range(5)),
            [("double", lambda x: x * 2), ("inc", lambda x: x + 1)])
        assert outs == [1, 3, 5, 7, 9]
        assert report.n_chunks == 5
        assert all(s.items == 5 for s in report.stages)

    def test_run_pipeline_propagates_errors(self):
        def boom(x):
            if x == 2:
                raise ValueError("x=2")
            return x

        with pytest.raises(ValueError, match="x=2"):
            run_pipeline(list(range(5)), [("a", boom), ("b", lambda x: x)])


# -------------------------------------------------------------- LoadFuture
class TestOpenAsync:
    def test_open_equals_open_async_result(self, disk):
        key = ModelKey("jax", "m0")
        disk.put(key, _tensors())
        mrm = _mrm(disk)
        fut = mrm.open_async(key)
        h = fut.result(timeout=30)
        assert fut.done() and fut.state == "ready"
        assert h.timings.tier_hit == "disk"
        assert mrm.refcount(key) == 1
        h2 = mrm.open(key)
        assert h2.timings.tier_hit == "device"
        mrm.close(h)
        mrm.close(h2)

    def test_error_propagates_through_future(self, disk):
        mrm = _mrm(disk)
        fut = mrm.open_async(ModelKey("jax", "nope"))
        assert isinstance(fut.exception(timeout=30), FileNotFoundError)
        with pytest.raises(FileNotFoundError):
            fut.result(timeout=30)
        with pytest.raises(FileNotFoundError):
            mrm.open(ModelKey("jax", "nope"))

    def test_concurrent_open_async_coalesces_to_one_load(self, disk):
        key = ModelKey("jax", "hot")
        disk.put(key, _tensors(4 * MB))
        mrm = _mrm(disk)
        futs = [mrm.open_async(key) for _ in range(8)]
        handles = [f.result(timeout=60) for f in futs]
        assert mrm.metrics["disk_loads"] == 1
        assert mrm.metrics["coalesced_loads"] >= 7
        assert mrm.refcount(key) == 8
        w0 = handles[0].weights["w0"]
        assert all(h.weights["w0"] is w0 for h in handles)
        for h in handles:
            mrm.close(h)
        assert mrm.refcount(key) == 0

    def test_threaded_open_still_single_load(self, disk):
        key = ModelKey("jax", "herd")
        disk.put(key, _tensors(4 * MB))
        mrm = _mrm(disk)
        handles, errs = [], []

        def worker():
            try:
                handles.append(mrm.open(key))
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        ts = [threading.Thread(target=worker) for _ in range(6)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs and len(handles) == 6
        assert mrm.metrics["disk_loads"] == 1
        for h in handles:
            mrm.close(h)


# ------------------------------------------------------ pipelined staging
class TestPipelinedStaging:
    def test_multichunk_values_correct(self, disk):
        key = ModelKey("jax", "chunky")
        t = _tensors(2 * MB, n=16, seed=3)
        disk.put(key, t)
        mrm = _mrm(disk, staging_chunk_bytes=64 << 10)  # force many chunks
        h = mrm.open(key)
        assert h.timings.chunks > 1
        assert h.timings.stage_overlap_s >= 0.0
        assert mrm.metrics["pipelined_loads"] == 1
        for k in t:
            np.testing.assert_array_equal(np.asarray(h.weights[k]), t[k])
        mrm.close(h)

    def test_modeled_pipelined_below_serial(self, disk):
        key = ModelKey("jax", "modeled")
        disk.put(key, _tensors(2 * MB, n=8))
        mrm = _mrm(disk, staging_chunk_bytes=256 << 10)
        h = mrm.open(key)
        t = h.timings
        assert 0 < t.staging_pipelined_modeled_s < t.staging_serial_modeled_s
        mrm.close(h)

    def test_shm_host_tier_pipelined(self, disk):
        key = ModelKey("jax", "shmod")
        t = _tensors(2 * MB, n=8, seed=9)
        disk.put(key, t)
        mrm = _mrm(disk, use_shm=True, staging_chunk_bytes=64 << 10)
        h = mrm.open(key, tier="host")
        for k in t:
            np.testing.assert_array_equal(np.asarray(h.weights[k]), t[k])
        mrm.close(h)
        h.weights = {}  # views must die before the segment unlinks
        for e in list(mrm.host.entries.values()):
            if e.payload is not None:
                e.payload.release()

    def test_serial_mode_still_works(self, disk):
        key = ModelKey("jax", "serial")
        t = _tensors(seed=5)
        disk.put(key, t)
        mrm = _mrm(disk, pipelined_staging=False)
        h = mrm.open(key)
        assert h.timings.chunks == 0
        for k in t:
            np.testing.assert_array_equal(np.asarray(h.weights[k]), t[k])
        mrm.close(h)


# ------------------------------------------------------------- demotion
class TestDemotion:
    def test_device_eviction_demotes_to_host_with_bytes_accounted(self, disk):
        k1, k2 = ModelKey("jax", "a"), ModelKey("jax", "b")
        disk.put(k1, _tensors(4 * MB, seed=1))
        disk.put(k2, _tensors(4 * MB, seed=2))
        mrm = _mrm(disk, dev=5 * MB, host=32 * MB)
        h1 = mrm.open(k1)
        mrm.close(h1)
        # simulate host-tier pressure: k1's host copy is gone, device remains
        e = mrm.host.remove(k1)
        e.payload.release()
        assert not mrm.resident(k1, Tier.HOST)
        assert mrm.resident(k1, Tier.DEVICE)

        h2 = mrm.open(k2)  # device full -> evicts k1 -> demote into HOST
        assert mrm.resident(k1, Tier.HOST)
        assert not mrm.resident(k1, Tier.DEVICE)
        # bytes accounted: demoted k1 + k2's own host copy
        assert mrm.host.used == (mrm.host.peek(k1).nbytes
                                 + mrm.host.peek(k2).nbytes)
        assert mrm.stats()["demotions"] == 1
        assert mrm.stats()["bytes_demoted"] == mrm.host.peek(k1).nbytes
        mrm.close(h2)

        # the demoted copy serves the next open as a HOST hit, not a reload
        loads_before = mrm.metrics["disk_loads"]
        h3 = mrm.open(k1)
        assert h3.timings.tier_hit == "host"
        assert mrm.metrics["disk_loads"] == loads_before
        np.testing.assert_array_equal(
            np.asarray(h3.weights["w0"]),
            _tensors(4 * MB, seed=1)["w0"])
        mrm.close(h3)

    def test_drop_on_evict_reloads_from_disk(self, disk):
        k1, k2 = ModelKey("jax", "a"), ModelKey("jax", "b")
        disk.put(k1, _tensors(4 * MB, seed=1))
        disk.put(k2, _tensors(4 * MB, seed=2))
        mrm = _mrm(disk, dev=5 * MB, host=32 * MB, demote_on_evict=False)
        h1 = mrm.open(k1)
        mrm.close(h1)
        e = mrm.host.remove(k1)
        e.payload.release()
        h2 = mrm.open(k2)
        mrm.close(h2)
        assert not mrm.resident(k1, Tier.HOST)  # dropped, not demoted
        h3 = mrm.open(k1)
        assert h3.timings.tier_hit == "disk"
        mrm.close(h3)

    def test_rotation_with_demotion_avoids_disk(self, disk):
        keys = [ModelKey("jax", f"m{i}") for i in range(3)]
        for i, k in enumerate(keys):
            disk.put(k, _tensors(4 * MB, seed=i))
        loads = {}
        for demote in (False, True):
            mrm = _mrm(disk, dev=10 * MB, host=10 * MB,
                       demote_on_evict=demote)
            for _ in range(3):
                for k in keys:
                    mrm.close(mrm.open(k))
            loads[demote] = mrm.metrics["disk_loads"]
        assert loads[True] < loads[False]

    def test_refcounted_entries_never_demoted(self, disk):
        k1, k2 = ModelKey("jax", "a"), ModelKey("jax", "b")
        disk.put(k1, _tensors(4 * MB, seed=1))
        disk.put(k2, _tensors(4 * MB, seed=2))
        mrm = _mrm(disk, dev=5 * MB)
        h1 = mrm.open(k1)  # hold the reference
        with pytest.raises(CapacityError):
            mrm.open(k2)
        assert mrm.resident(k1, Tier.DEVICE)
        assert mrm.stats()["demotions"] == 0
        mrm.close(h1)

    def test_pinned_entries_never_evicted(self, disk):
        k1, k2 = ModelKey("jax", "a"), ModelKey("jax", "b")
        disk.put(k1, _tensors(4 * MB, seed=1))
        disk.put(k2, _tensors(4 * MB, seed=2))
        mrm = _mrm(disk, dev=5 * MB)
        mrm.close(mrm.open(k1))
        assert mrm.pin(k1)
        with pytest.raises(CapacityError):
            mrm.open(k2)
        assert mrm.unpin(k1)
        h = mrm.open(k2)
        assert not mrm.resident(k1, Tier.DEVICE)
        mrm.close(h)


# -------------------------------------------------------------- prefetch
class TestPrefetch:
    def test_prefetch_warms_device_without_refs(self, disk):
        key = ModelKey("jax", "warm")
        disk.put(key, _tensors())
        mrm = _mrm(disk)
        fut = mrm.prefetch(key)
        assert fut.result(timeout=60) is None
        assert mrm.resident(key, Tier.DEVICE)
        assert mrm.refcount(key) == 0
        assert mrm.metrics["prefetches"] == 1
        h = mrm.open(key)
        assert h.timings.tier_hit == "device"
        assert mrm.metrics["disk_loads"] == 1
        mrm.close(h)

    def test_open_coalesces_onto_prefetch(self, disk):
        key = ModelKey("jax", "race")
        disk.put(key, _tensors(4 * MB))
        mrm = _mrm(disk)
        fut = mrm.prefetch(key)
        h = mrm.open(key)  # either coalesces or hits the finished prefetch
        fut.result(timeout=60)
        assert mrm.metrics["disk_loads"] == 1
        assert mrm.refcount(key) == 1
        mrm.close(h)

    def test_client_and_platform_prewarm(self, disk):
        from repro.core import FaaSPlatform
        key = ModelKey("jax", "alex")
        disk.put(key, _tensors())
        mrm = _mrm(disk)
        platform = FaaSPlatform(mrm)
        c = platform.deploy("f", lambda ctx, p: ctx.load_model("jax", "alex"),
                            allowed_models=[("jax", "alex")])
        assert mrm.metrics["prefetches"] == 1
        platform.invoke("f")
        assert mrm.metrics["disk_loads"] == 1  # prewarm + invoke = one load
        assert c.acct.cold_starts == 0


# ------------------------------------------------------------- cost model
class TestStagingCostModel:
    def test_pipelined_strictly_below_serial_when_chunked(self):
        hw = HardwareModel()
        n = 256 * MB
        assert hw.staging_pipelined_time(n) < hw.staging_serial_time(n)

    def test_single_chunk_equals_serial(self):
        hw = HardwareModel()
        n = 1 * MB
        np.testing.assert_allclose(hw.staging_pipelined_time(n, chunk_bytes=2 * MB),
                                   hw.staging_serial_time(n), rtol=1e-9)

    def test_pipelined_approaches_max_stage_bound(self):
        hw = HardwareModel()
        n = 1 << 30
        bound = max(n / hw.disk_bw, n / hw.cached_read_bw, n / hw.h2d_bw)
        pipe = hw.staging_pipelined_time(n, chunk_bytes=1 * MB)
        assert pipe < hw.staging_serial_time(n)
        assert pipe >= bound  # cannot beat the slowest stage


# ------------------------------------------------- engine version keying
class TestEngineVersioning:
    def test_cfg_cache_keyed_by_name_and_version(self, tmp_path):
        jax = pytest.importorskip("jax")
        from repro.configs import get_config
        from repro.models import init_params
        from repro.serving import InferenceEngine, publish_model

        disk = DiskStore(str(tmp_path / "models"))
        base = get_config("olmo-1b").reduced()
        cfg1 = base.replace(n_layers=1)
        cfg2 = base.replace(n_layers=2)
        publish_model(disk, cfg1, init_params(cfg1, jax.random.PRNGKey(0)),
                      name="olmo-1b", version="1")
        publish_model(disk, cfg2, init_params(cfg2, jax.random.PRNGKey(1)),
                      name="olmo-1b", version="2")
        mrm = MRM(disk, device_capacity=1 << 30)
        engine = InferenceEngine(disk, mrm)
        sm1, _ = engine.load_model("olmo-1b", "1")
        sm2, _ = engine.load_model("olmo-1b", "2")
        assert sm1.cfg.n_layers == 1
        assert sm2.cfg.n_layers == 2  # pre-fix: silently reused version 1 cfg
        engine.release(sm1)
        engine.release(sm2)
