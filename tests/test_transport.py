"""Transport layer: framing, partial I/O robustness, socket RPC,
loopback parity (DESIGN.md §11)."""
from __future__ import annotations

import os
import socket
import tempfile
import threading
import time

import pytest

from repro.core.transport import (LoopbackTransport, RemoteError,
                                  SocketServer, SocketTransport,
                                  TransportError, parse_address, recv_chunk,
                                  recv_frame, recvn, send_chunk, send_frame,
                                  sendall)


def sockpair():
    a, b = socket.socketpair()
    return a, b


# ---------------------------------------------------------------------------
# framing primitives
# ---------------------------------------------------------------------------

class TestFraming:
    def test_frame_roundtrip(self):
        a, b = sockpair()
        try:
            send_frame(a, {"op": "x", "n": 3, "blob": b"\x00\xff",
                           "nested": {"k": [1, 2]}})
            got = recv_frame(b)
            assert got == {"op": "x", "n": 3, "blob": b"\x00\xff",
                           "nested": {"k": [1, 2]}}
        finally:
            a.close(); b.close()

    def test_int_map_keys_survive(self):
        # directory snapshots key views by int shard id
        a, b = sockpair()
        try:
            send_frame(a, {"views": {0: "a", 7: "b"}})
            assert recv_frame(b)["views"] == {0: "a", 7: "b"}
        finally:
            a.close(); b.close()

    def test_clean_eof_returns_none(self):
        a, b = sockpair()
        a.close()
        try:
            assert recv_frame(b) is None
        finally:
            b.close()

    def test_mid_frame_eof_raises(self):
        a, b = sockpair()
        try:
            send_frame(a, {"op": "x", "pad": b"\x00" * 1024})
            # peek the total frame size, then deliver only part of it
            data = b.recv(4, socket.MSG_PEEK)
            assert len(data) == 4
        finally:
            a.close()
        # drain a prefix, then EOF mid-frame
        b.recv(10)
        with pytest.raises(TransportError):
            while True:
                if recv_frame(b) is None:
                    raise AssertionError("expected TransportError, got EOF")
        b.close()

    def test_eof_between_frame_header_and_body_raises(self):
        # kill -9 can land the EOF exactly between the 4-byte length
        # prefix and the msgpack body: that must be a TransportError in
        # the OSError taxonomy, never a TypeError from unpackb(None)
        import struct
        a, b = sockpair()
        a.sendall(struct.pack("<I", 10))
        a.close()
        with pytest.raises(TransportError, match="between frame header"):
            recv_frame(b)
        b.close()

    def test_eof_between_chunk_header_and_body_raises(self):
        # same boundary inside a byte stream: truncation, not a clean
        # end-of-stream marker
        import struct
        a, b = sockpair()
        a.sendall(struct.pack("<I", 10))
        a.close()
        with pytest.raises(TransportError, match="between chunk header"):
            recv_chunk(b)
        b.close()

    def test_oversized_frame_rejected(self):
        a, b = sockpair()
        try:
            import struct
            a.sendall(struct.pack("<I", (1 << 30)))
            with pytest.raises(TransportError, match="exceeds cap"):
                recv_frame(b)
        finally:
            a.close(); b.close()

    def test_chunk_stream_roundtrip(self):
        a, b = sockpair()
        try:
            chunks = [b"abc", b"d" * 70000, b"e"]
            for c in chunks:
                send_chunk(a, c)
            send_chunk(a, b"")  # end of stream
            got = []
            while True:
                c = recv_chunk(b)
                if c is None:
                    break
                got.append(c)
            assert b"".join(got) == b"".join(chunks)
        finally:
            a.close(); b.close()

    def test_parse_address(self):
        kind, where = parse_address("unix:/tmp/x.sock")
        assert kind == "unix" and where == "/tmp/x.sock"
        kind, where = parse_address("tcp:127.0.0.1:8080")
        assert kind == "tcp" and where == ("127.0.0.1", 8080)
        with pytest.raises(ValueError):
            parse_address("http://nope")


# ---------------------------------------------------------------------------
# partial-write / EINTR robustness (the satellite around _send/_recvn)
# ---------------------------------------------------------------------------

class _DribbleSock:
    """Fake socket: sends one byte at a time, injects EINTR, records all
    bytes; recv side serves from a buffer one byte at a time."""

    def __init__(self, rx: bytes = b""):
        self.sent = bytearray()
        self.rx = rx
        self.pos = 0
        self.calls = 0

    def send(self, data) -> int:
        self.calls += 1
        if self.calls % 3 == 0:
            raise InterruptedError  # EINTR: must be retried, not fatal
        self.sent += bytes(data[:1])
        return 1

    def recv(self, n: int) -> bytes:
        self.calls += 1
        if self.calls % 3 == 0:
            raise InterruptedError
        if self.pos >= len(self.rx):
            return b""
        b = self.rx[self.pos:self.pos + 1]
        self.pos += 1
        return b


class TestPartialIO:
    def test_sendall_survives_short_writes_and_eintr(self):
        s = _DribbleSock()
        payload = os.urandom(257)
        sendall(s, payload)
        assert bytes(s.sent) == payload

    def test_recvn_reassembles_one_byte_reads(self):
        payload = os.urandom(129)
        s = _DribbleSock(rx=payload)
        assert recvn(s, len(payload)) == payload

    def test_recvn_clean_eof_none_mid_eof_raises(self):
        assert recvn(_DribbleSock(rx=b""), 8) is None
        with pytest.raises(TransportError, match="mid-frame"):
            recvn(_DribbleSock(rx=b"abc"), 8)

    def test_send_timeout_is_transport_error(self):
        class _T:
            def send(self, data):
                raise socket.timeout("timed out")
        with pytest.raises(TransportError):
            sendall(_T(), b"x" * 10)


# ---------------------------------------------------------------------------
# socket server + client
# ---------------------------------------------------------------------------

def _echo_handler(req):
    op = req["op"]
    if op == "echo":
        return {"ok": True, "back": req.get("x")}
    if op == "boom":
        raise ValueError("kaput")
    if op == "stream":
        def chunks():
            for i in range(req["n"]):
                yield bytes([i]) * req["size"]
        return {"ok": True, "stream": True}, chunks()
    if op == "stream_dies":
        def chunks():
            yield b"first"
            raise IOError("source vanished")
        return {"ok": True, "stream": True}, chunks()
    if op == "slow":
        time.sleep(req["s"])
        return {"ok": True}
    raise ValueError(f"unknown {op}")


@pytest.fixture
def server():
    tmp = tempfile.mkdtemp(prefix="transport-test-")
    srv = SocketServer(_echo_handler, f"unix:{tmp}/rpc.sock")
    yield srv
    srv.stop()


class TestSocketRPC:
    def test_call_roundtrip(self, server):
        t = SocketTransport(server.address)
        assert t.call({"op": "echo", "x": [1, "two", b"3"]})["back"] == \
            [1, "two", b"3"]
        t.close()

    def test_remote_exception_becomes_remote_error(self, server):
        t = SocketTransport(server.address)
        with pytest.raises(RemoteError, match="ValueError: kaput"):
            t.call({"op": "boom"})
        # the connection survives a remote error (no reconnect needed)
        assert t.call({"op": "echo", "x": 1})["back"] == 1
        t.close()

    def test_streaming_body(self, server):
        t = SocketTransport(server.address)
        got = []
        resp = t.call_stream({"op": "stream", "n": 5, "size": 70000},
                             got.append)
        assert resp["ok"]
        assert b"".join(got) == b"".join(bytes([i]) * 70000
                                         for i in range(5))
        t.close()

    def test_stream_source_death_fails_trailer(self, server):
        t = SocketTransport(server.address)
        got = []
        with pytest.raises(RemoteError, match="source vanished"):
            t.call_stream({"op": "stream_dies"}, got.append)
        assert got == [b"first"]  # partial bytes delivered then aborted
        t.close()

    def test_concurrent_clients(self, server):
        errs = []

        def worker(i):
            try:
                t = SocketTransport(server.address)
                for j in range(20):
                    assert t.call({"op": "echo",
                                   "x": i * 100 + j})["back"] == i * 100 + j
                t.close()
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        ts = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
        for th in ts:
            th.start()
        for th in ts:
            th.join()
        assert not errs

    def test_call_timeout_surfaces_as_transport_error(self, server):
        t = SocketTransport(server.address, timeout_s=0.2)
        with pytest.raises(TransportError):
            t.call({"op": "slow", "s": 2.0})
        t.close()

    def test_reconnect_after_idle_close(self):
        tmp = tempfile.mkdtemp(prefix="transport-idle-")
        srv = SocketServer(_echo_handler, f"unix:{tmp}/rpc.sock",
                           idle_timeout_s=0.2)
        try:
            t = SocketTransport(srv.address)
            assert t.call({"op": "echo", "x": 1})["back"] == 1
            time.sleep(0.6)  # server dropped the idle connection
            # pooled-connection retry: the stale socket is replaced
            assert t.call({"op": "echo", "x": 2})["back"] == 2
            t.close()
        finally:
            srv.stop()

    def test_tcp_ephemeral_port(self):
        srv = SocketServer(_echo_handler, "tcp:127.0.0.1:0")
        try:
            assert srv.address.startswith("tcp:127.0.0.1:")
            assert not srv.address.endswith(":0")
            t = SocketTransport(srv.address)
            assert t.call({"op": "echo", "x": "tcp"})["back"] == "tcp"
            t.close()
        finally:
            srv.stop()

    def test_connect_to_dead_server_is_oserror(self):
        with pytest.raises(OSError):
            SocketTransport("unix:/nonexistent/nope.sock").call({"op": "e"})

    def test_mid_stream_failure_is_never_retried(self, tmp_path):
        """A TransportError AFTER this request's response started (server
        dies mid-stream) must not trigger the stale-connection retry: a
        resent stream would duplicate into a sink that already consumed
        partial chunks. The request must reach the server exactly once."""
        path = str(tmp_path / "half.sock")
        srv = socket.socket(socket.AF_UNIX)
        srv.bind(path)
        srv.listen(4)
        requests = []

        def serve():
            while True:
                try:
                    conn, _ = srv.accept()
                except OSError:
                    return
                while True:
                    req = recv_frame(conn)
                    if req is None:
                        conn.close()
                        break
                    requests.append(req["op"])
                    if req["op"] == "echo":
                        send_frame(conn, {"ok": True})
                        continue
                    # streaming header + one chunk, then an abrupt close
                    send_frame(conn, {"ok": True, "stream": True,
                                      "nbytes": 12})
                    send_chunk(conn, b"part")
                    conn.close()
                    break

        threading.Thread(target=serve, daemon=True).start()
        try:
            t = SocketTransport(f"unix:{path}", timeout_s=5)
            # a completed exchange first: the connection is reused (not
            # fresh) when the stream fails, which is exactly the state
            # the broken guard used to retry from
            assert t.call({"op": "echo"})["ok"]
            got = []
            with pytest.raises(TransportError):
                t.call_stream({"op": "stream"}, got.append)
            assert got == [b"part"], "sink must hold only the half-stream"
            assert requests == ["echo", "stream"], \
                f"half-stream request was resent: {requests}"
            # the transport recovers on the next request (new connection)
            assert t.call({"op": "echo"})["ok"]
            t.close()
        finally:
            srv.close()

    def test_failure_before_response_on_reused_conn_still_retries(self):
        """The legitimate retry — a pooled connection the server closed
        idle — must keep working after the mid-stream guard tightened."""
        tmp = tempfile.mkdtemp(prefix="transport-retry-")
        srv = SocketServer(_echo_handler, f"unix:{tmp}/rpc.sock",
                           idle_timeout_s=0.2)
        try:
            t = SocketTransport(srv.address)
            got = []
            t.call_stream({"op": "stream", "n": 2, "size": 10}, got.append)
            assert len(b"".join(got)) == 20
            time.sleep(0.6)  # server drops the idle connection
            got2 = []
            resp = t.call_stream({"op": "stream", "n": 2, "size": 10},
                                 got2.append)
            assert resp["ok"] and len(b"".join(got2)) == 20
            t.close()
        finally:
            srv.stop()


# ---------------------------------------------------------------------------
# loopback parity
# ---------------------------------------------------------------------------

class TestLoopback:
    def test_same_surface_as_socket(self):
        t = LoopbackTransport(_echo_handler)
        assert t.remote is False
        assert t.call({"op": "echo", "x": 5})["back"] == 5
        with pytest.raises(RemoteError, match="ValueError: kaput"):
            t.call({"op": "boom"})
        got = []
        resp = t.call_stream({"op": "stream", "n": 3, "size": 10},
                             got.append)
        assert resp["ok"] and len(b"".join(got)) == 30

    def test_wire_type_normalization(self):
        # requests round-trip through msgpack: tuples become lists, so
        # in-process handlers see exactly what socket handlers see
        seen = {}

        def handler(req):
            seen.update(req)
            return {"ok": True}

        LoopbackTransport(handler).call({"op": "x", "key": ("jax", "m", "1")})
        assert seen["key"] == ["jax", "m", "1"]
