"""TrIMS core: store format, tier cache, MRM state machine, sharing model."""
import os
import threading

import numpy as np
import pytest

from repro.core import (
    CapacityError, CloudStore, DiskStore, LCU, LRU, MRM, ModelKey, Tier,
    TierCache, cold_load, load_model, rho, plan_granularity,
)
from repro.core.sharing import SharingConstants
from repro.core.store import ModelFile, write_model

MB = 1 << 20


def _tensors(nbytes=1 * MB, n=4, seed=0):
    rng = np.random.default_rng(seed)
    per = nbytes // n // 4
    return {f"w{i}": rng.standard_normal(per).astype(np.float32) for i in range(n)}


@pytest.fixture
def disk(tmp_path):
    return DiskStore(str(tmp_path / "disk"))


def _mrm(disk, cloud=None, dev=8 * MB, host=32 * MB, **kw):
    return MRM(disk, cloud, device_capacity=dev, host_capacity=host, **kw)


# ---------------------------------------------------------------- store
class TestStore:
    def test_roundtrip(self, tmp_path):
        t = _tensors()
        p = str(tmp_path / "m.trims")
        write_model(p, t, meta={"hello": 1})
        mf = ModelFile(p)
        assert mf.meta == {"hello": 1}
        out = mf.read_all(verify=True)
        for k in t:
            np.testing.assert_array_equal(out[k], t[k])

    def test_layer_granular_read(self, tmp_path):
        t = _tensors(n=8)
        p = str(tmp_path / "m.trims")
        write_model(p, t)
        mf = ModelFile(p)
        np.testing.assert_array_equal(mf.read_tensor("w3", verify=True), t["w3"])
        np.testing.assert_array_equal(np.asarray(mf.mmap_tensor("w5")), t["w5"])

    def test_checksum_detects_corruption(self, tmp_path):
        t = _tensors(n=1)
        p = str(tmp_path / "m.trims")
        write_model(p, t)
        mf = ModelFile(p)
        tm = mf.tensors["w0"]
        with open(p, "r+b") as f:
            f.seek(mf.payload_base + tm.offset + 100)
            f.write(b"\xff\xff\xff\xff")
        with pytest.raises(IOError):
            ModelFile(p).read_tensor("w0", verify=True)

    def test_bfloat16_roundtrip(self, tmp_path):
        import jax.numpy as jnp
        arr = np.asarray(jnp.arange(64, dtype=jnp.bfloat16))
        p = str(tmp_path / "bf.trims")
        write_model(p, {"x": arr})
        out = ModelFile(p).read_all()["x"]
        assert str(out.dtype) == "bfloat16"
        np.testing.assert_array_equal(np.asarray(out, np.float32),
                                      np.asarray(arr, np.float32))


# ---------------------------------------------------------------- cache
class TestTierCache:
    def test_capacity_and_eviction_lru(self):
        c = TierCache(Tier.DEVICE, 100, LRU())
        c.insert("a", 40)
        c.insert("b", 40)
        c.get("a")  # a more recent than b
        ev = c.make_room(40)
        assert [e.key for e in ev] == ["b"]
        assert c.used == 40

    def test_lcu_order(self):
        c = TierCache(Tier.DEVICE, 100, LCU())
        c.insert("a", 40)
        c.insert("b", 40)
        for _ in range(3):
            c.get("b")
        ev = c.make_room(40)
        assert [e.key for e in ev] == ["a"]

    def test_referenced_never_evicted(self):
        c = TierCache(Tier.DEVICE, 100, LRU())
        e = c.insert("a", 60, refcount=1)
        c.insert("b", 30)
        with pytest.raises(CapacityError):
            c.make_room(50)  # would need to evict "a" but it's referenced
        e.refcount = 0
        ev = c.make_room(50)
        assert {x.key for x in ev} >= {"a"}

    def test_oversized_rejected(self):
        c = TierCache(Tier.DEVICE, 100, LRU())
        with pytest.raises(CapacityError):
            c.make_room(101)


# ---------------------------------------------------------------- MRM
class TestMRM:
    def test_cold_then_warm(self, disk):
        key = ModelKey("jax", "m0")
        disk.put(key, _tensors())
        mrm = _mrm(disk)
        h1 = mrm.open(key)
        assert h1.timings.tier_hit == "disk"
        assert mrm.refcount(key) == 1
        h2 = mrm.open(key)
        assert h2.timings.tier_hit == "device"
        assert mrm.refcount(key) == 2
        # warm hit must be much faster than the cold path
        assert h2.timings.total_s < max(h1.timings.total_s, 1e-3)
        # shared arrays: same underlying buffer
        assert h1.weights["w0"] is h2.weights["w0"]
        mrm.close(h1)
        mrm.close(h2)
        assert mrm.refcount(key) == 0
        # default: lazily retained (paper: MRM keeps zero-ref models)
        assert mrm.resident(key, Tier.DEVICE)

    def test_cloud_miss_path(self, disk, tmp_path):
        cloud = CloudStore(str(tmp_path / "cloud"), simulate_time=False)
        key = ModelKey("jax", "remote-model")
        cloud.put(key, _tensors())
        mrm = _mrm(disk, cloud)
        h = mrm.open(key)
        assert h.timings.tier_hit == "cloud"
        assert h.timings.cloud_s > 0
        assert disk.contains(key)  # downloaded into local storage
        mrm.close(h)

    def test_host_hit_after_device_eviction(self, disk):
        k1, k2 = ModelKey("jax", "a"), ModelKey("jax", "b")
        disk.put(k1, _tensors(5 * MB, seed=1))
        disk.put(k2, _tensors(5 * MB, seed=2))
        mrm = _mrm(disk, dev=6 * MB, host=32 * MB)
        h1 = mrm.open(k1)
        mrm.close(h1)
        h2 = mrm.open(k2)  # evicts m1 from device; host copy remains
        assert not mrm.resident(k1, Tier.DEVICE)
        assert mrm.resident(k1, Tier.HOST)
        mrm.close(h2)
        h3 = mrm.open(k1)
        assert h3.timings.tier_hit == "host"
        mrm.close(h3)

    def test_eviction_never_frees_in_use(self, disk):
        k1, k2 = ModelKey("jax", "a"), ModelKey("jax", "b")
        disk.put(k1, _tensors(5 * MB, seed=1))
        disk.put(k2, _tensors(5 * MB, seed=2))
        mrm = _mrm(disk, dev=6 * MB)
        h1 = mrm.open(k1)  # hold the ref
        with pytest.raises(CapacityError):
            mrm.open(k2)
        mrm.close(h1)
        h2 = mrm.open(k2)
        mrm.close(h2)

    def test_eager_reclaim(self, disk):
        key = ModelKey("jax", "m0")
        disk.put(key, _tensors())
        mrm = _mrm(disk, eager_reclaim=True)
        h = mrm.open(key)
        mrm.close(h)
        assert not mrm.resident(key, Tier.DEVICE)

    def test_thundering_herd_dedup(self, disk):
        key = ModelKey("jax", "hot")
        disk.put(key, _tensors(8 * MB))
        mrm = _mrm(disk, dev=32 * MB)
        handles, errs = [], []

        def worker():
            try:
                handles.append(mrm.open(key))
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        ts = [threading.Thread(target=worker) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert not errs
        assert len(handles) == 8
        assert mrm.metrics["disk_loads"] == 1  # exactly one real load
        assert mrm.refcount(key) == 8
        for h in handles:
            mrm.close(h)

    def test_values_correct_through_cache(self, disk):
        key = ModelKey("jax", "val")
        t = _tensors(seed=42)
        disk.put(key, t)
        mrm = _mrm(disk)
        h = mrm.open(key)
        for k in t:
            np.testing.assert_allclose(np.asarray(h.weights[k]), t[k], rtol=0)
        mrm.close(h)


# ---------------------------------------------------------------- client
class TestClient:
    def test_load_model_transparent(self, disk):
        key = ModelKey("jax", "m0")
        disk.put(key, _tensors())
        # baseline: cold load (framework without TrIMS)
        m_cold = load_model("jax", "m0", disk=disk)
        assert not m_cold.via_trims
        # TrIMS path: same return structure
        from repro.core import TrimsClient
        mrm = _mrm(disk)
        client = TrimsClient(mrm)
        m_trims = load_model("jax", "m0", trims=client)
        assert m_trims.via_trims
        assert set(m_cold.weights) == set(m_trims.weights)
        np.testing.assert_array_equal(np.asarray(m_cold.weights["w1"]),
                                      np.asarray(m_trims.weights["w1"]))


# ---------------------------------------------------------------- sharing
class TestSharing:
    CONSTS = SharingConstants(o=1e-4, s=5e-5, q=500e6)

    def test_rho_sign(self):
        # 1 GB at model granularity: clearly positive
        assert rho(1 << 30, 1, self.CONSTS) > 0
        # tiny model, thousands of objects: negative
        assert rho(1 << 10, 4096, self.CONSTS) < 0

    def test_rho_monotonic_in_size_and_objects(self):
        r = [rho(b, 4, self.CONSTS) for b in (1 * MB, 16 * MB, 256 * MB)]
        assert r == sorted(r)
        r2 = [rho(64 * MB, n, self.CONSTS) for n in (1, 16, 256)]
        assert r2 == sorted(r2, reverse=True)

    def test_plan_granularity(self):
        # large layers -> layer granularity wins
        gran, n, r = plan_granularity([64 * MB] * 16, self.CONSTS)
        assert gran == "layer" and r > 0
        # many tiny layers -> fall back to coarser granularity (paper:
        # ResNet269-v2 layer-level sharing overhead remediation)
        gran, n, r = plan_granularity([1024] * 2000, self.CONSTS)
        assert gran in ("layer_group", "model")
